//! Schedule commands — the TACO scheduling language plus the Sgap
//! extension (§5.1): `parallelize` now accepts `GPUGroup` with a
//! [`GroupSpec`], and `GPUWarp` keeps only tiling semantics.
//!
//! A [`Schedule`] is an ordered command list applied to a tensor algebra
//! statement, paired with the kernel-kind config ([`KernelConfig`]) whose
//! tuning parameters the commands were instantiated from.
//! [`Schedule::to_cin`] produces the concrete index notation (the paper's
//! Listings 3–6 and the §4.3 generalizations); [`Schedule::classify`]
//! recognizes which algorithm [`Family`] the command list describes, and
//! [`Schedule::reduction_plan`] extracts the [`ReductionPlan`] the
//! lowerer's family-agnostic emission pipeline consumes.
//!
//! Every kernel the catalog exposes — the four SpMM families, the grouped
//! SDDMM of §4.3, the dgSPARSE RB+PR library shape, and the COO-3
//! MTTKRP/TTM segment families — is described here and lowered through
//! [`crate::compiler::lower`](mod@crate::compiler::lower) (entered via `compiler::compile`, which
//! checks each schedule against its stated [`TensorAlgebra`]); there are
//! no hand-assembled LLIR kernels outside the compiler.

use std::fmt;

use super::cin::{
    Cin, GroupSpec, OutputRaceStrategy, ParallelUnit, ReductionPlan, ReductionStrategy, Writeback,
};
use super::expr::{Access, Expr, IndexVar, TensorAlgebra};

/// One scheduling command (subset of TACO's API used by the paper).
#[derive(Debug, Clone, PartialEq)]
pub enum ScheduleCmd {
    /// `fuse(i, j, f)` — fuse two index vars into one.
    Fuse { a: IndexVar, b: IndexVar, into: IndexVar },
    /// `pos(f, fpos, A(i,j))` — move to position space of a tensor level.
    Pos { var: IndexVar, pos_var: IndexVar, access: Access },
    /// `split(v, outer, inner, factor)`.
    Split { var: IndexVar, outer: IndexVar, inner: IndexVar, factor: u32 },
    /// `bound(v, bv, extent, MaxExact)`.
    Bound { var: IndexVar, bound_var: IndexVar, extent: u32 },
    /// `reorder(vars...)`.
    Reorder { order: Vec<IndexVar> },
    /// `precompute(expr, v, workspace)` — scalar workspace (§5.3).
    Precompute { workspace: String },
    /// `parallelize(v, unit, race)` — stock TACO form.
    Parallelize { var: IndexVar, unit: ParallelUnit, race: OutputRaceStrategy },
    /// `parallelize(v, GPUGroup, r, strategy)` — the Sgap form.
    ParallelizeGroup { var: IndexVar, spec: GroupSpec, race: OutputRaceStrategy },
}

impl fmt::Display for ScheduleCmd {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ScheduleCmd::Fuse { a, b, into } => write!(f, "fuse({a},{b},{into})"),
            ScheduleCmd::Pos { var, pos_var, access } => write!(f, "pos({var},{pos_var},{access})"),
            ScheduleCmd::Split { var, outer, inner, factor } => {
                write!(f, "split({var},{outer},{inner},{factor})")
            }
            ScheduleCmd::Bound { var, bound_var, extent } => {
                write!(f, "bound({var},{bound_var},{extent},MaxExact)")
            }
            ScheduleCmd::Reorder { order } => {
                let s: Vec<String> = order.iter().map(|v| v.to_string()).collect();
                write!(f, "reorder({})", s.join(","))
            }
            ScheduleCmd::Precompute { workspace } => write!(f, "precompute({workspace})"),
            ScheduleCmd::Parallelize { var, unit, race } => {
                write!(f, "parallelize({var},{unit},{race})")
            }
            ScheduleCmd::ParallelizeGroup { var, spec, race } => {
                write!(f, "parallelize({var},GPUGroup,{},{},{race})", spec.size, spec.strategy)
            }
        }
    }
}

/// Tunable parameters shared by all four SpMM schedules.
///
/// `n` = dense columns, `c` = coarsening (cols per thread), `p` = threads
/// per block, `g` = the data granularity (nnz per thread, or threads per
/// row), `r` = reduction parallelism (GroupSize), `x` = rows per thread.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpmmConfig {
    pub n: u32,
    pub c: u32,
    pub p: u32,
    pub g: u32,
    pub r: u32,
    pub x: u32,
}

impl Default for SpmmConfig {
    fn default() -> Self {
        SpmmConfig { n: 4, c: 4, p: 256, g: 32, r: 32, x: 1 }
    }
}

impl SpmmConfig {
    /// Column-chunks per row tile: how many thread-columns cover N.
    /// (Callers must `validate()` first; a non-dividing `c` is reported
    /// there, not here.)
    pub fn kchunks(&self) -> u32 {
        (self.n / self.c).max(1)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.n % self.c != 0 {
            return Err(format!("c={} must divide N={}", self.c, self.n));
        }
        if !self.r.is_power_of_two() || self.r > 32 {
            return Err(format!("r={} must be a power of 2 <= 32", self.r));
        }
        if !self.g.is_power_of_two() && self.g != self.p {
            // g is a thread-grouping factor in row-group schedules
        }
        if self.p % self.kchunks() != 0 {
            return Err(format!("p={} must be divisible by N/c={}", self.p, self.kchunks()));
        }
        Ok(())
    }
}

/// Tunable SDDMM configuration (§4.3): `Y = A ⊙ (X1 · X2)` with `g` lanes
/// cooperating per non-zero over the dense `j` reduction, grouped tree
/// reduction of width `r`, `p` threads per block.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SddmmConfig {
    pub j_dim: u32,
    /// Lanes cooperating per non-zero (power of 2, ≤ 32).
    pub g: u32,
    /// Reduction parallelism (GroupSize), `r <= g`.
    pub r: u32,
    /// Threads per block.
    pub p: u32,
}

impl SddmmConfig {
    pub fn new(j_dim: u32, g: u32, r: u32) -> Self {
        SddmmConfig { j_dim, g, r, p: 256 }
    }

    pub fn validate(&self) -> Result<(), String> {
        if !self.g.is_power_of_two() || self.g > 32 {
            return Err(format!("g={} must be a power of 2 <= 32", self.g));
        }
        if !self.r.is_power_of_two() || self.r > self.g {
            return Err(format!("r={} must be a power of 2 <= g={}", self.r, self.g));
        }
        if self.p == 0 || self.p % self.g != 0 {
            return Err(format!("p={} must be a positive multiple of g={}", self.p, self.g));
        }
        Ok(())
    }

    /// Non-zeros per block. (The `.max(1)` keeps schedule construction
    /// total for configs `validate()` rejects, e.g. `g = 0`.)
    pub fn npb(&self) -> u32 {
        self.p / self.g.max(1)
    }
}

/// Tunable fused SDDMM→SpMM configuration: the attention chain
/// `C = (A ⊙ (X1 · X2)) · B` lowered as **one** nnz-split kernel. Each
/// nnz-owning lane computes the SDDMM dot over the dense `j_dim` (here
/// named `l` in the algebra) in-register, then feeds it straight into the
/// SpMM segment-group reduction over `n` output columns — no `Y` buffer,
/// one pass over `pos`/`crd`. Launch shape matches the Listing-6 SpMM
/// family: `c` output columns per thread, `p` threads per block, `r`-wide
/// segment reduction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FusedConfig {
    /// Dense dot length (the producer's reduction, X1 columns).
    pub j_dim: u32,
    /// Dense output columns (B/C width).
    pub n: u32,
    /// Column coarsening: output columns per thread.
    pub c: u32,
    /// Threads per block.
    pub p: u32,
    /// Reduction parallelism (GroupSize) of the consumer's segment
    /// reduction.
    pub r: u32,
}

impl FusedConfig {
    pub fn new(j_dim: u32, n: u32, c: u32, r: u32) -> Self {
        FusedConfig { j_dim, n, c, p: 256, r }
    }

    /// Column-chunks per tile (guarded like [`MttkrpConfig::kchunks`]).
    pub fn kchunks(&self) -> u32 {
        (self.n / self.c.max(1)).max(1)
    }

    /// Non-zeros per block.
    pub fn npb(&self) -> u32 {
        (self.p / self.kchunks()).max(1)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.j_dim == 0 {
            return Err("fused SDDMM dot needs j_dim >= 1".into());
        }
        validate_coo3_shape("N", self.n, self.c, self.p, self.r)
    }
}

/// One point in the dgSPARSE tuning space (§7.2): a block processes
/// `tile_sz` real columns; `worker_sz` threads process one vectorized
/// column (of `coarsen_sz` real columns) of one sparse row; `group_sz`
/// threads synchronize (the atomic-parallelism tuning axis);
/// `worker_dim_r_frac` scales the total row parallelism — when it is less
/// than the number of rows, each worker loops rows with that stride
/// (row balance, the `RowBalancedPartial` strategy).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DgConfig {
    pub n: u32,
    pub group_sz: u32,
    pub block_sz: u32,
    pub tile_sz: u32,
    /// Row parallelism as a fraction of #rows: `workerDimR = frac * rows`
    /// (the paper tunes powers/reciprocal-powers of 2 of the original).
    pub worker_dim_r_frac: f64,
    pub worker_sz: u32,
    pub coarsen_sz: u32,
}

impl DgConfig {
    /// The library's default configuration for a given N (§7.2).
    pub fn stock(n: u32) -> Self {
        DgConfig {
            n,
            group_sz: 32,
            block_sz: 256,
            tile_sz: 32,
            worker_dim_r_frac: 1.0,
            worker_sz: 32,
            coarsen_sz: if n % 4 == 0 {
                4
            } else if n % 2 == 0 {
                2
            } else {
                1
            },
        }
    }

    /// Vectorized columns per block. (The `.max(1)` keeps schedule
    /// construction total for configs `validate()` rejects.)
    pub fn vcols(&self) -> u32 {
        self.n.min(self.tile_sz) / self.coarsen_sz.max(1)
    }

    /// blockDim.x = min(N, tileSz)/coarsenSz * workerSz (§7.2).
    pub fn block_dim_x(&self) -> u32 {
        self.vcols() * self.worker_sz
    }

    pub fn rows_per_block(&self) -> u32 {
        // the .max(1) on blockDim.x keeps schedule *construction* total
        // for configs validate() rejects (e.g. coarsenSz > min(N, tileSz))
        (self.block_sz / self.block_dim_x().max(1)).max(1)
    }

    pub fn col_tiles(&self) -> u32 {
        self.n.div_ceil(self.tile_sz)
    }

    pub fn validate(&self) -> Result<(), String> {
        if !self.group_sz.is_power_of_two() || self.group_sz > 32 {
            return Err("groupSz must be a power of 2 <= 32".into());
        }
        if self.group_sz > self.worker_sz {
            return Err("groupSz must be <= workerSz (a group must not straddle rows)".into());
        }
        if !self.tile_sz.is_power_of_two() || self.tile_sz < self.group_sz {
            return Err("tileSz must be a power of 2 >= groupSz".into());
        }
        if self.coarsen_sz == 0 || self.n.min(self.tile_sz) % self.coarsen_sz != 0 {
            return Err("coarsenSz must be >= 1 and divide min(N, tileSz)".into());
        }
        if self.block_dim_x() > self.block_sz {
            return Err(format!(
                "blockDim.x {} exceeds blockSz {}",
                self.block_dim_x(),
                self.block_sz
            ));
        }
        if self.block_sz % self.block_dim_x().max(1) != 0 {
            // trailing threads would compute rowb == rows_per_block and
            // double-count the next block's first row
            return Err(format!(
                "blockSz {} must be a multiple of blockDim.x {}",
                self.block_sz,
                self.block_dim_x()
            ));
        }
        if self.block_sz > 1024 {
            return Err("blockSz must be <= 1024".into());
        }
        if self.worker_dim_r_frac <= 0.0 {
            return Err("workerDimR fraction must be positive".into());
        }
        Ok(())
    }

    /// Total row-worker parallelism for a matrix with `rows` rows,
    /// rounded **up to whole blocks** — the row-loop stride must equal the
    /// number of actually-spawned workers or trailing workers would
    /// double-count rows.
    pub fn worker_dim_r(&self, rows: usize) -> u32 {
        let rpb = self.rows_per_block();
        let want = ((rows as f64 * self.worker_dim_r_frac).round() as u32).max(rpb);
        want.div_ceil(rpb) * rpb
    }

    /// Launch grid: row blocks × column tiles.
    pub fn grid(&self, rows: usize) -> u32 {
        let row_blocks = self.worker_dim_r(rows) / self.rows_per_block();
        row_blocks * self.col_tiles()
    }
}

/// Tunable MTTKRP configuration (Eq. 2a): `Y(i,j) = Σ A(i,k,l)·X1(k,j)·
/// X2(l,j)` as a COO-3 nnz-split grouped **segment reduction** keyed by
/// the output row `i` — the same `segReduceGroup` macro instruction as
/// SpMM's Listing-6 kernel (§2.1's "the reductions behave the same").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MttkrpConfig {
    /// Dense factor columns J (`Y` is `[dim0 × J]`).
    pub j_dim: u32,
    /// Column coarsening: factor columns per thread.
    pub c: u32,
    /// Threads per block.
    pub p: u32,
    /// Reduction parallelism (GroupSize).
    pub r: u32,
}

impl MttkrpConfig {
    pub fn new(j_dim: u32, c: u32, r: u32) -> Self {
        MttkrpConfig { j_dim, c, p: 256, r }
    }

    /// Column-chunks per tile: how many thread-columns cover J. (The
    /// guards keep schedule construction total for configs `validate()`
    /// rejects.)
    pub fn kchunks(&self) -> u32 {
        (self.j_dim / self.c.max(1)).max(1)
    }

    /// Non-zeros per block: the nnz-owning lanes of each column chunk.
    pub fn npb(&self) -> u32 {
        (self.p / self.kchunks()).max(1)
    }

    pub fn validate(&self) -> Result<(), String> {
        validate_coo3_shape("J", self.j_dim, self.c, self.p, self.r)
    }
}

/// Tunable TTM configuration (Eq. 2b): `Y(i,j,l) = Σ A(i,j,k)·X1(k,l)` as
/// a COO-3 nnz-split grouped segment reduction keyed by the leading
/// `(i,j)` fiber.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TtmConfig {
    /// Dense output columns L (`Y` is `[(dim0·dim1) × L]`).
    pub l_dim: u32,
    /// Column coarsening: output columns per thread.
    pub c: u32,
    /// Threads per block.
    pub p: u32,
    /// Reduction parallelism (GroupSize).
    pub r: u32,
}

impl TtmConfig {
    pub fn new(l_dim: u32, c: u32, r: u32) -> Self {
        TtmConfig { l_dim, c, p: 256, r }
    }

    /// Column-chunks per tile (guarded like [`MttkrpConfig::kchunks`]).
    pub fn kchunks(&self) -> u32 {
        (self.l_dim / self.c.max(1)).max(1)
    }

    /// Non-zeros per block.
    pub fn npb(&self) -> u32 {
        (self.p / self.kchunks()).max(1)
    }

    pub fn validate(&self) -> Result<(), String> {
        validate_coo3_shape("L", self.l_dim, self.c, self.p, self.r)
    }
}

/// The shared launch-shape rules of the COO-3 nnz-split families: `c`
/// divides the dense width, the column chunks divide the block, and the
/// group is a power of two no wider than the contiguous nnz range a
/// block's lanes own (`r <= npb`, the segmented-scan precondition).
fn validate_coo3_shape(axis: &str, width: u32, c: u32, p: u32, r: u32) -> Result<(), String> {
    if width == 0 || c == 0 || width % c != 0 {
        return Err(format!("c={c} must be >= 1 and divide {axis}={width}"));
    }
    let kchunks = width / c;
    if p == 0 || p % kchunks != 0 {
        return Err(format!("p={p} must be a positive multiple of {axis}/c={kchunks}"));
    }
    if !r.is_power_of_two() || r > 32 {
        return Err(format!("r={r} must be a power of 2 <= 32"));
    }
    let npb = p / kchunks;
    if r > npb {
        return Err(format!(
            "r={r} exceeds the {npb} consecutive non-zeros a block's lanes own \
             (an aligned r-group must see a contiguous nnz range)"
        ));
    }
    Ok(())
}

/// The kernel-kind payload of a [`Schedule`] — one compiled-plan
/// vocabulary across SpMM, SDDMM, MTTKRP, TTM, and the dgSPARSE library
/// shape.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum KernelConfig {
    Spmm(SpmmConfig),
    Sddmm(SddmmConfig),
    /// dgSPARSE RB+PR point; `workerDimR` is resolved at launch from the
    /// matrix's row count and bound as a scalar kernel parameter.
    Dg(DgConfig),
    Mttkrp(MttkrpConfig),
    Ttm(TtmConfig),
    /// Fused SDDMM→SpMM — the producer's dot computed in-register inside
    /// the consumer's nnz-split segment reduction.
    Fused(FusedConfig),
}

impl KernelConfig {
    pub fn validate(&self) -> Result<(), String> {
        match self {
            KernelConfig::Spmm(c) => c.validate(),
            KernelConfig::Sddmm(c) => c.validate(),
            KernelConfig::Dg(c) => c.validate(),
            KernelConfig::Mttkrp(c) => c.validate(),
            KernelConfig::Ttm(c) => c.validate(),
            KernelConfig::Fused(c) => c.validate(),
        }
    }

    /// Short kind label for diagnostics.
    pub fn kind(&self) -> &'static str {
        match self {
            KernelConfig::Spmm(_) => "Spmm",
            KernelConfig::Sddmm(_) => "Sddmm",
            KernelConfig::Dg(_) => "Dg",
            KernelConfig::Mttkrp(_) => "Mttkrp",
            KernelConfig::Ttm(_) => "Ttm",
            KernelConfig::Fused(_) => "Fused",
        }
    }
}

/// The algorithm families the lowerer emits: the four SpMM families of
/// §6, the grouped SDDMM of §4.3, the dgSPARSE RB+PR library shape, and
/// the COO-3 MTTKRP/TTM segment families (Eq. 2a/2b).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    /// `{<g nnz, c col>, 1}` — Listing 3 (EB + serial reduction).
    NnzSerial,
    /// `{<x row, c col>, 1}` — Listing 4 (RB + serial reduction).
    RowSerial,
    /// `{<1/g row, c col>, r}` — Listing 5 (RB + grouped parallel reduction).
    RowGroup,
    /// `{<1 nnz, c col>, r}` — Listing 6 (EB + grouped segment reduction).
    NnzGroup,
    /// SDDMM `{<1/g nnz>, r}` — §4.3's grouped dot-product reduction.
    SddmmGroup,
    /// dgSPARSE RB+PR+RM — row-balanced strided rows, grouped parallel
    /// reduction with partial results per row visit.
    DgRowBalanced,
    /// MTTKRP `{<1 nnz, c col>, r}` — COO-3 nnz split, grouped segment
    /// reduction keyed by the output row.
    MttkrpGroup,
    /// TTM `{<1 nnz, c col>, r}` — COO-3 nnz split, grouped segment
    /// reduction keyed by the leading `(i,j)` fiber.
    TtmGroup,
    /// Fused SDDMM→SpMM `{<1 nnz, c col>, r}` — the attention chain in
    /// one traversal: in-register dot per nonzero, segment-group SpMM
    /// writeback.
    FusedSddmmSpmm,
}

impl fmt::Display for Family {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Family::NnzSerial => "nnz-serial {<g nnz, c col>, 1}",
            Family::RowSerial => "row-serial {<x row, c col>, 1}",
            Family::RowGroup => "row-group {<1/g row, c col>, r}",
            Family::NnzGroup => "nnz-group {<1 nnz, c col>, r}",
            Family::SddmmGroup => "sddmm-group {<1/g nnz>, r}",
            Family::DgRowBalanced => "dgsparse-rb-pr",
            Family::MttkrpGroup => "mttkrp-group {<1 nnz, c col>, r}",
            Family::TtmGroup => "ttm-group {<1 nnz, c col>, r}",
            Family::FusedSddmmSpmm => "fused-sddmm-spmm {<1 nnz, c col>, r}",
        };
        write!(f, "{s}")
    }
}

/// A complete schedule: the commands plus resolved tuning parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct Schedule {
    pub cmds: Vec<ScheduleCmd>,
    pub config: KernelConfig,
}

impl Schedule {
    // ---- the four canonical schedules (Listings 3–6) --------------------

    /// Listing 3: `{<g nnz, c col>, 1}` — original TACO nnz-split.
    pub fn taco_nnz_serial(config: SpmmConfig) -> Schedule {
        let v = |s: &str| IndexVar::new(s);
        let nnz_per_block = config.g * (config.p / config.kchunks());
        Schedule {
            cmds: vec![
                ScheduleCmd::Fuse { a: v("i"), b: v("j"), into: v("f") },
                ScheduleCmd::Pos { var: v("f"), pos_var: v("fpos"), access: Access::new("A", &["i", "j"]) },
                ScheduleCmd::Split { var: v("fpos"), outer: v("block"), inner: v("fpos1"), factor: nnz_per_block },
                ScheduleCmd::Split { var: v("fpos1"), outer: v("warp"), inner: v("fpos2"), factor: config.g },
                ScheduleCmd::Split { var: v("k"), outer: v("ko"), inner: v("ki"), factor: config.c },
                ScheduleCmd::Bound { var: v("ko"), bound_var: v("ko"), extent: config.kchunks() },
                ScheduleCmd::Precompute { workspace: "tmp".into() },
                ScheduleCmd::Parallelize { var: v("block"), unit: ParallelUnit::GPUBlock, race: OutputRaceStrategy::IgnoreRaces },
                ScheduleCmd::Parallelize { var: v("warp"), unit: ParallelUnit::GPUWarp, race: OutputRaceStrategy::NoRaces },
                ScheduleCmd::Parallelize { var: v("fpos2"), unit: ParallelUnit::GPUThread, race: OutputRaceStrategy::Atomics },
            ],
            config: KernelConfig::Spmm(config),
        }
    }

    /// Listing 4: `{<x row, c col>, 1}` — original TACO row-split.
    pub fn taco_row_serial(config: SpmmConfig) -> Schedule {
        let v = |s: &str| IndexVar::new(s);
        let rows_per_block = config.x * config.p / config.kchunks();
        Schedule {
            cmds: vec![
                ScheduleCmd::Split { var: v("i"), outer: v("block"), inner: v("io"), factor: rows_per_block },
                ScheduleCmd::Split { var: v("io"), outer: v("warp"), inner: v("ii"), factor: config.x },
                ScheduleCmd::Split { var: v("k"), outer: v("ko"), inner: v("ki"), factor: config.c },
                ScheduleCmd::Bound { var: v("ko"), bound_var: v("ko"), extent: config.kchunks() },
                ScheduleCmd::Parallelize { var: v("block"), unit: ParallelUnit::GPUBlock, race: OutputRaceStrategy::NoRaces },
                ScheduleCmd::Parallelize { var: v("ii"), unit: ParallelUnit::GPUThread, race: OutputRaceStrategy::NoRaces },
            ],
            config: KernelConfig::Spmm(config),
        }
    }

    /// Listing 5: `{<1/g row, c col>, r}` — Sgap row-split with grouped
    /// parallel reduction (`atomicAddGroup<float, r>`).
    pub fn sgap_row_group(config: SpmmConfig, r: u32) -> Schedule {
        let v = |s: &str| IndexVar::new(s);
        let mut config = config;
        config.r = r;
        Schedule {
            cmds: vec![
                ScheduleCmd::Fuse { a: v("i"), b: v("k"), into: v("io") },
                ScheduleCmd::Split { var: v("io"), outer: v("ko"), inner: v("ki"), factor: config.c * config.p / config.g },
                ScheduleCmd::Split { var: v("ki"), outer: v("warp"), inner: v("kii"), factor: config.c },
                ScheduleCmd::Pos { var: v("j"), pos_var: v("jpos"), access: Access::new("A", &["i", "j"]) },
                ScheduleCmd::Split { var: v("jpos"), outer: v("jpos0"), inner: v("jpos1"), factor: config.g },
                ScheduleCmd::Parallelize { var: v("ko"), unit: ParallelUnit::GPUBlock, race: OutputRaceStrategy::NoRaces },
                ScheduleCmd::Parallelize { var: v("warp"), unit: ParallelUnit::GPUWarp, race: OutputRaceStrategy::Atomics },
                ScheduleCmd::ParallelizeGroup {
                    var: v("jpos1"),
                    spec: GroupSpec::new(r, ReductionStrategy::ParallelReduction),
                    race: OutputRaceStrategy::Atomics,
                },
            ],
            config: KernelConfig::Spmm(config),
        }
    }

    /// Listing 6: `{<1 nnz, c col>, r}` — Sgap nnz-split with grouped
    /// segment reduction (`segReduceGroup<float, r>`).
    pub fn sgap_nnz_group(config: SpmmConfig, r: u32) -> Schedule {
        let v = |s: &str| IndexVar::new(s);
        let mut config = config;
        config.r = r;
        let nnz_per_block = config.p / config.kchunks();
        Schedule {
            cmds: vec![
                ScheduleCmd::Fuse { a: v("i"), b: v("j"), into: v("f") },
                ScheduleCmd::Pos { var: v("f"), pos_var: v("fpos"), access: Access::new("A", &["i", "j"]) },
                ScheduleCmd::Split { var: v("fpos"), outer: v("block"), inner: v("fpos1"), factor: nnz_per_block },
                ScheduleCmd::Split { var: v("k"), outer: v("ko"), inner: v("ki"), factor: config.c },
                ScheduleCmd::Bound { var: v("ko"), bound_var: v("warp"), extent: config.kchunks() },
                ScheduleCmd::Precompute { workspace: "tmp".into() },
                ScheduleCmd::Parallelize { var: v("block"), unit: ParallelUnit::GPUBlock, race: OutputRaceStrategy::IgnoreRaces },
                ScheduleCmd::Parallelize { var: v("warp"), unit: ParallelUnit::GPUWarp, race: OutputRaceStrategy::NoRaces },
                ScheduleCmd::Parallelize { var: v("fpos1"), unit: ParallelUnit::GPUThread, race: OutputRaceStrategy::Atomics },
                ScheduleCmd::ParallelizeGroup {
                    var: v("fpos1"),
                    spec: GroupSpec::new(r, ReductionStrategy::SegmentReduction),
                    race: OutputRaceStrategy::Atomics,
                },
            ],
            config: KernelConfig::Spmm(config),
        }
    }

    /// §4.3 SDDMM `{<1/g nnz>, r}`: `g` lanes cooperate on one non-zero,
    /// each striding the dense `j` reduction by `g`; a grouped tree
    /// reduction of width `r` combines the partial dot products — the
    /// *same* `atomicAddGroup` macro instruction as SpMM's row kernel,
    /// demonstrating that segment group is not SpMM-specific.
    pub fn sddmm_group(config: SddmmConfig) -> Schedule {
        let v = |s: &str| IndexVar::new(s);
        Schedule {
            cmds: vec![
                ScheduleCmd::Fuse { a: v("i"), b: v("k"), into: v("f") },
                ScheduleCmd::Pos { var: v("f"), pos_var: v("fpos"), access: Access::new("A", &["i", "k"]) },
                ScheduleCmd::Split { var: v("fpos"), outer: v("block"), inner: v("e"), factor: config.npb() },
                ScheduleCmd::Split { var: v("j"), outer: v("jo"), inner: v("lane"), factor: config.g },
                ScheduleCmd::Reorder { order: vec![v("block"), v("e"), v("lane"), v("jo")] },
                ScheduleCmd::Parallelize { var: v("block"), unit: ParallelUnit::GPUBlock, race: OutputRaceStrategy::NoRaces },
                ScheduleCmd::Parallelize { var: v("e"), unit: ParallelUnit::GPUThread, race: OutputRaceStrategy::NoRaces },
                ScheduleCmd::ParallelizeGroup {
                    var: v("lane"),
                    // literal spec: invalid sizes are reported by
                    // KernelConfig::validate at lowering, not asserted here
                    spec: GroupSpec {
                        size: config.r,
                        strategy: ReductionStrategy::ParallelReduction,
                    },
                    race: OutputRaceStrategy::Atomics,
                },
            ],
            config: KernelConfig::Sddmm(config),
        }
    }

    /// dgSPARSE's RB+PR+RM kernel as a schedule: rows strided by
    /// `workerDimR` (row balance), `worker_sz` lanes striding each row's
    /// non-zeros, grouped parallel reduction writing a partial result per
    /// row visit ([`ReductionStrategy::RowBalancedPartial`]).
    pub fn dgsparse_rb_pr(config: DgConfig) -> Schedule {
        let v = |s: &str| IndexVar::new(s);
        Schedule {
            cmds: vec![
                ScheduleCmd::Split { var: v("i"), outer: v("row_block"), inner: v("rowb"), factor: config.rows_per_block() },
                ScheduleCmd::Split { var: v("k"), outer: v("col_block"), inner: v("kt"), factor: config.tile_sz },
                ScheduleCmd::Split { var: v("kt"), outer: v("vcol"), inner: v("cc"), factor: config.coarsen_sz },
                ScheduleCmd::Pos { var: v("j"), pos_var: v("jpos"), access: Access::new("A", &["i", "j"]) },
                ScheduleCmd::Split { var: v("jpos"), outer: v("jo"), inner: v("lane"), factor: config.worker_sz },
                ScheduleCmd::Reorder { order: vec![v("row_block"), v("col_block"), v("rowb"), v("vcol"), v("cc"), v("lane"), v("jo")] },
                ScheduleCmd::Parallelize { var: v("row_block"), unit: ParallelUnit::GPUBlock, race: OutputRaceStrategy::NoRaces },
                ScheduleCmd::Parallelize { var: v("vcol"), unit: ParallelUnit::GPUWarp, race: OutputRaceStrategy::NoRaces },
                ScheduleCmd::ParallelizeGroup {
                    var: v("lane"),
                    // literal spec: invalid sizes are reported by
                    // KernelConfig::validate at lowering, not asserted here
                    spec: GroupSpec {
                        size: config.group_sz,
                        strategy: ReductionStrategy::RowBalancedPartial,
                    },
                    race: OutputRaceStrategy::Atomics,
                },
            ],
            config: KernelConfig::Dg(config),
        }
    }

    /// MTTKRP (Eq. 2a) as a schedule: fuse the three sparse coordinates
    /// into the COO position space, one non-zero per thread × `c` factor
    /// columns, grouped **segment reduction** keyed by the output row `i`
    /// — the same `segReduceGroup` macro instruction as Listing 6.
    pub fn mttkrp_group(config: MttkrpConfig) -> Schedule {
        let v = |s: &str| IndexVar::new(s);
        Schedule {
            cmds: vec![
                ScheduleCmd::Fuse { a: v("i"), b: v("k"), into: v("ik") },
                ScheduleCmd::Fuse { a: v("ik"), b: v("l"), into: v("f") },
                ScheduleCmd::Pos { var: v("f"), pos_var: v("fpos"), access: Access::new("A", &["i", "k", "l"]) },
                ScheduleCmd::Split { var: v("fpos"), outer: v("block"), inner: v("fpos1"), factor: config.npb() },
                ScheduleCmd::Split { var: v("j"), outer: v("ko"), inner: v("ki"), factor: config.c },
                ScheduleCmd::Bound { var: v("ko"), bound_var: v("ko"), extent: config.kchunks() },
                ScheduleCmd::Precompute { workspace: "val".into() },
                ScheduleCmd::Parallelize { var: v("block"), unit: ParallelUnit::GPUBlock, race: OutputRaceStrategy::IgnoreRaces },
                ScheduleCmd::Parallelize { var: v("ko"), unit: ParallelUnit::GPUWarp, race: OutputRaceStrategy::NoRaces },
                ScheduleCmd::ParallelizeGroup {
                    var: v("fpos1"),
                    // literal spec: invalid sizes are reported by
                    // KernelConfig::validate at lowering, not asserted here
                    spec: GroupSpec {
                        size: config.r,
                        strategy: ReductionStrategy::SegmentReduction,
                    },
                    race: OutputRaceStrategy::Atomics,
                },
            ],
            config: KernelConfig::Mttkrp(config),
        }
    }

    /// TTM (Eq. 2b) as a schedule: same COO-3 nnz-split shape as MTTKRP,
    /// segment-reduced over the leading `(i,j)` fiber.
    pub fn ttm_group(config: TtmConfig) -> Schedule {
        let v = |s: &str| IndexVar::new(s);
        Schedule {
            cmds: vec![
                ScheduleCmd::Fuse { a: v("i"), b: v("j"), into: v("ij") },
                ScheduleCmd::Fuse { a: v("ij"), b: v("k"), into: v("f") },
                ScheduleCmd::Pos { var: v("f"), pos_var: v("fpos"), access: Access::new("A", &["i", "j", "k"]) },
                ScheduleCmd::Split { var: v("fpos"), outer: v("block"), inner: v("fpos1"), factor: config.npb() },
                ScheduleCmd::Split { var: v("l"), outer: v("ko"), inner: v("ki"), factor: config.c },
                ScheduleCmd::Bound { var: v("ko"), bound_var: v("ko"), extent: config.kchunks() },
                ScheduleCmd::Precompute { workspace: "val".into() },
                ScheduleCmd::Parallelize { var: v("block"), unit: ParallelUnit::GPUBlock, race: OutputRaceStrategy::IgnoreRaces },
                ScheduleCmd::Parallelize { var: v("ko"), unit: ParallelUnit::GPUWarp, race: OutputRaceStrategy::NoRaces },
                ScheduleCmd::ParallelizeGroup {
                    var: v("fpos1"),
                    // literal spec: see mttkrp_group
                    spec: GroupSpec {
                        size: config.r,
                        strategy: ReductionStrategy::SegmentReduction,
                    },
                    race: OutputRaceStrategy::Atomics,
                },
            ],
            config: KernelConfig::Ttm(config),
        }
    }

    /// Fused SDDMM→SpMM as a schedule: the Listing-6 nnz-split shape over
    /// the flattened attention algebra, with the producer's dot held in
    /// the `tlaneY` scalar workspace (§5.3's relaxed rule) instead of a
    /// materialized `Y` — one pass over `pos`/`crd`, one grouped segment
    /// reduction.
    pub fn fused_sddmm_spmm(config: FusedConfig) -> Schedule {
        let v = |s: &str| IndexVar::new(s);
        Schedule {
            cmds: vec![
                ScheduleCmd::Fuse { a: v("i"), b: v("j"), into: v("f") },
                ScheduleCmd::Pos { var: v("f"), pos_var: v("fpos"), access: Access::new("A", &["i", "j"]) },
                ScheduleCmd::Split { var: v("fpos"), outer: v("block"), inner: v("fpos1"), factor: config.npb() },
                ScheduleCmd::Split { var: v("k"), outer: v("ko"), inner: v("ki"), factor: config.c },
                ScheduleCmd::Bound { var: v("ko"), bound_var: v("warp"), extent: config.kchunks() },
                ScheduleCmd::Precompute { workspace: "tlaneY".into() },
                ScheduleCmd::Parallelize { var: v("block"), unit: ParallelUnit::GPUBlock, race: OutputRaceStrategy::IgnoreRaces },
                ScheduleCmd::Parallelize { var: v("warp"), unit: ParallelUnit::GPUWarp, race: OutputRaceStrategy::NoRaces },
                ScheduleCmd::Parallelize { var: v("fpos1"), unit: ParallelUnit::GPUThread, race: OutputRaceStrategy::Atomics },
                ScheduleCmd::ParallelizeGroup {
                    var: v("fpos1"),
                    // literal spec: invalid sizes are reported by
                    // KernelConfig::validate at lowering, not asserted here
                    spec: GroupSpec {
                        size: config.r,
                        strategy: ReductionStrategy::SegmentReduction,
                    },
                    race: OutputRaceStrategy::Atomics,
                },
            ],
            config: KernelConfig::Fused(config),
        }
    }

    // ---- analysis --------------------------------------------------------

    /// The tensor algebra statement this schedule lowers — derived from
    /// the kernel-kind config, so every `Schedule` names its algebra and
    /// `compiler::compile` can reject schedule/expression mismatches.
    pub fn algebra(&self) -> TensorAlgebra {
        match self.config {
            KernelConfig::Spmm(_) | KernelConfig::Dg(_) => TensorAlgebra::spmm(),
            KernelConfig::Sddmm(_) => TensorAlgebra::sddmm(),
            KernelConfig::Mttkrp(_) => TensorAlgebra::mttkrp(),
            KernelConfig::Ttm(_) => TensorAlgebra::ttm(),
            KernelConfig::Fused(_) => TensorAlgebra::fused_sddmm_spmm(),
        }
    }

    /// The grouped parallelize binding, if any: the scheduled index var
    /// and its [`GroupSpec`].
    pub fn group_binding(&self) -> Option<(IndexVar, GroupSpec)> {
        self.cmds.iter().find_map(|c| match c {
            ScheduleCmd::ParallelizeGroup { var, spec, .. } => Some((var.clone(), *spec)),
            _ => None,
        })
    }

    /// The source index variables a (possibly derived) schedule variable
    /// traces back to, walking the command list backwards through
    /// `split`/`fuse`/`pos`/`bound` provenance. A grouped reduction is
    /// only meaningful when its variable's roots intersect the algebra's
    /// `reduction_dims()` — the check `compiler::compile` enforces.
    pub fn roots_of(&self, var: &IndexVar) -> Vec<IndexVar> {
        fn replace(frontier: &mut Vec<IndexVar>, from: &IndexVar, to: &[&IndexVar]) {
            if let Some(pos) = frontier.iter().position(|v| v == from) {
                frontier.remove(pos);
                for t in to {
                    if !frontier.contains(*t) {
                        frontier.push((*t).clone());
                    }
                }
            }
        }
        let mut frontier = vec![var.clone()];
        for cmd in self.cmds.iter().rev() {
            match cmd {
                ScheduleCmd::Split { var: src, outer, inner, .. } => {
                    replace(&mut frontier, outer, &[src]);
                    replace(&mut frontier, inner, &[src]);
                }
                ScheduleCmd::Fuse { a, b, into } => replace(&mut frontier, into, &[a, b]),
                ScheduleCmd::Pos { var: src, pos_var, .. } => {
                    replace(&mut frontier, pos_var, &[src])
                }
                ScheduleCmd::Bound { var: src, bound_var, .. } => {
                    replace(&mut frontier, bound_var, &[src])
                }
                _ => {}
            }
        }
        frontier
    }

    /// The SpMM tuning parameters, if this schedule describes one of the
    /// four SpMM families.
    pub fn spmm_config(&self) -> Option<SpmmConfig> {
        match self.config {
            KernelConfig::Spmm(c) => Some(c),
            _ => None,
        }
    }

    /// Identify which algorithm family the command list describes.
    ///
    /// Stock TACO (before Sgap) rejects anything with `GPUGroup`; here it
    /// is a first-class citizen. Grouped strategies classify by their
    /// **writeback discipline**, so a user-defined
    /// [`ReductionStrategy::Custom`] routes through the same families as
    /// the built-ins — the pipeline needs no edits per strategy.
    /// Unrecognized command shapes are an error — the lowerer supports
    /// exactly the shapes the paper exercises.
    pub fn classify(&self) -> Result<Family, String> {
        match self.config {
            KernelConfig::Spmm(_) => self.classify_spmm(),
            KernelConfig::Sddmm(_) => match self.group_cmd() {
                // both grouped writebacks are sound here: an aligned
                // r-subgroup sees one group-uniform output slot per nnz
                Some(spec) if spec.strategy.writeback().is_grouped() => Ok(Family::SddmmGroup),
                Some(spec) => Err(format!(
                    "SDDMM's dense-j reduction needs a grouped writeback, got {}",
                    spec.strategy.writeback()
                )),
                None => Err("SDDMM schedules require a GPUGroup parallelize".into()),
            },
            KernelConfig::Dg(_) => match self.group_cmd() {
                Some(spec) if spec.strategy.writeback().is_grouped() => {
                    Ok(Family::DgRowBalanced)
                }
                _ => Err("dgSPARSE schedules require a grouped GPUGroup reduction".into()),
            },
            KernelConfig::Mttkrp(_) => {
                self.classify_coo3_seg("MTTKRP").map(|()| Family::MttkrpGroup)
            }
            KernelConfig::Ttm(_) => self.classify_coo3_seg("TTM").map(|()| Family::TtmGroup),
            KernelConfig::Fused(_) => self
                .classify_coo3_seg("fused SDDMM\u{2192}SpMM")
                .map(|()| Family::FusedSddmmSpmm),
        }
    }

    /// The COO-3 nnz-split families share one requirement: a grouped
    /// reduction with a **segment-boundary** writeback. The output index
    /// (one slot per output segment) is not group-uniform across an
    /// nnz-split lane group, so a lane-zero writeback would silently drop
    /// every segment but the first.
    fn classify_coo3_seg(&self, what: &str) -> Result<(), String> {
        match self.group_cmd() {
            Some(spec) if spec.strategy.writeback() == Writeback::SegmentBoundary => Ok(()),
            Some(spec) => Err(format!(
                "{what}'s nnz-split reduction needs a segment-boundary writeback, got {}",
                spec.strategy.writeback()
            )),
            None => Err(format!("{what} schedules require a GPUGroup parallelize")),
        }
    }

    fn classify_spmm(&self) -> Result<Family, String> {
        let has_pos = self.cmds.iter().any(|c| matches!(c, ScheduleCmd::Pos { .. }));
        let group = self.group_cmd();
        match (has_pos, group) {
            (true, Some(spec)) => match spec.strategy.writeback() {
                Writeback::SegmentBoundary => Ok(Family::NnzGroup),
                Writeback::LaneZeroAtomic => Ok(Family::RowGroup),
                wb => Err(format!("grouped SpMM schedules need a grouped writeback, got {wb}")),
            },
            (true, None) => {
                // pos without a group: nnz-split serial (Listing 3) unless the
                // pos var is the reduction var split for cooperative rows.
                let fused_ij = self.cmds.iter().any(|c| matches!(c, ScheduleCmd::Fuse { a, b, .. } if a.0 == "i" && b.0 == "j"));
                if fused_ij {
                    Ok(Family::NnzSerial)
                } else {
                    Err("pos-schedule without (i,j) fusion or GPUGroup is unsupported".into())
                }
            }
            (false, None) => Ok(Family::RowSerial),
            (false, Some(_)) => Err("GPUGroup requires a pos() schedule".into()),
        }
    }

    /// The reduction recipe this schedule's classification implies — the
    /// object every writeback in [`crate::compiler::lower`](mod@crate::compiler::lower) is emitted
    /// from. Grouped families inherit strategy, group size, and writeback
    /// from their [`GroupSpec`]; the serial families reduce in-register
    /// and write back with atomics (nnz split, shared outputs) or plain
    /// stores (row split, exclusive outputs).
    pub fn reduction_plan(&self) -> Result<ReductionPlan, String> {
        Ok(match self.classify()? {
            Family::RowSerial => ReductionPlan::serial(Writeback::Store),
            Family::NnzSerial => ReductionPlan::serial(Writeback::Atomic),
            Family::RowGroup
            | Family::NnzGroup
            | Family::SddmmGroup
            | Family::DgRowBalanced
            | Family::MttkrpGroup
            | Family::TtmGroup
            | Family::FusedSddmmSpmm => {
                self.group_cmd().expect("grouped families carry a GroupSpec").plan()
            }
        })
    }

    fn group_cmd(&self) -> Option<GroupSpec> {
        self.cmds.iter().find_map(|c| match c {
            ScheduleCmd::ParallelizeGroup { spec, .. } => Some(*spec),
            _ => None,
        })
    }

    /// Build the concrete index notation (Listings 3–6 shapes plus the
    /// §4.3 SDDMM and dgSPARSE RB+PR generalizations).
    pub fn to_cin(&self) -> Cin {
        let mul = Expr::Mul(
            Box::new(Expr::Access(Access::new("A", &["i", "j"]))),
            Box::new(Expr::Access(Access::new("B", &["j", "k"]))),
        );
        match self.classify().expect("unsupported schedule") {
            Family::SddmmGroup => {
                let spec = self.group_cmd().unwrap();
                let dot = Expr::Mul(
                    Box::new(Expr::Access(Access::new("X1", &["i", "j"]))),
                    Box::new(Expr::Access(Access::new("X2", &["j", "k"]))),
                );
                let producer = Cin::Assign {
                    lhs: Access::new("tlaneY", &[]),
                    reduce: true,
                    rhs: dot,
                };
                let jo = Cin::forall("jo", ParallelUnit::Serial, OutputRaceStrategy::NoRaces, producer);
                let consumer = Cin::Assign {
                    lhs: Access::new("Y", &["i", "k"]),
                    reduce: true,
                    rhs: Expr::Mul(
                        Box::new(Expr::Access(Access::new("A", &["i", "k"]))),
                        Box::new(Expr::Access(Access::new("tlaneY", &[]))),
                    ),
                };
                let wh = Cin::Where { consumer: Box::new(consumer), producer: Box::new(jo) };
                let lane = Cin::forall_group("lane", spec, OutputRaceStrategy::Atomics, wh);
                let e = Cin::forall("e", ParallelUnit::GPUThread, OutputRaceStrategy::NoRaces, lane);
                Cin::forall("block", ParallelUnit::GPUBlock, OutputRaceStrategy::NoRaces, e)
            }
            Family::DgRowBalanced => {
                let spec = self.group_cmd().unwrap();
                let producer = Cin::Assign {
                    lhs: Access::new("tlaneC", &[]),
                    reduce: true,
                    rhs: mul,
                };
                let jo = Cin::forall("jo", ParallelUnit::Serial, OutputRaceStrategy::NoRaces, producer);
                let consumer = Cin::Assign {
                    lhs: Access::new("C", &["i", "k"]),
                    reduce: true,
                    rhs: Expr::Access(Access::new("tlaneC", &[])),
                };
                let wh = Cin::Where { consumer: Box::new(consumer), producer: Box::new(jo) };
                let lane = Cin::forall_group("lane", spec, OutputRaceStrategy::Atomics, wh);
                let cc = Cin::forall("cc", ParallelUnit::Serial, OutputRaceStrategy::NoRaces, lane);
                let vcol = Cin::forall("vcol", ParallelUnit::GPUWarp, OutputRaceStrategy::NoRaces, cc);
                let rowb = Cin::forall("rowb", ParallelUnit::Serial, OutputRaceStrategy::NoRaces, vcol);
                Cin::forall("row_block", ParallelUnit::GPUBlock, OutputRaceStrategy::NoRaces, rowb)
            }
            Family::NnzSerial | Family::NnzGroup => {
                let strategy = self.group_cmd();
                let consumer = Cin::Assign {
                    lhs: Access::new("C", &["i", "k"]),
                    reduce: true,
                    rhs: Expr::Access(Access::new("tmp", &[])),
                };
                let producer = Cin::Assign {
                    lhs: Access::new("tmp", &[]),
                    reduce: strategy.is_none(), // serial family accumulates into tmp
                    rhs: mul,
                };
                let wh = Cin::Where { consumer: Box::new(consumer), producer: Box::new(producer) };
                let inner = match strategy {
                    Some(spec) => Cin::forall_group("fpos1", spec, OutputRaceStrategy::Atomics, wh),
                    None => Cin::forall("fpos2", ParallelUnit::GPUThread, OutputRaceStrategy::Atomics, wh),
                };
                let ki = Cin::forall("ki", ParallelUnit::Serial, OutputRaceStrategy::NoRaces, inner);
                let warp = Cin::forall("warp", ParallelUnit::GPUWarp, OutputRaceStrategy::NoRaces, ki);
                Cin::forall("block", ParallelUnit::GPUBlock, OutputRaceStrategy::IgnoreRaces, warp)
            }
            Family::RowSerial => {
                let asn = Cin::Assign { lhs: Access::new("C", &["i", "k"]), reduce: true, rhs: mul };
                let j = Cin::forall("j", ParallelUnit::Serial, OutputRaceStrategy::NoRaces, asn);
                let ki = Cin::forall("ki", ParallelUnit::Serial, OutputRaceStrategy::NoRaces, j);
                let ii = Cin::forall("ii", ParallelUnit::GPUThread, OutputRaceStrategy::NoRaces, ki);
                Cin::forall("block", ParallelUnit::GPUBlock, OutputRaceStrategy::NoRaces, ii)
            }
            family @ (Family::MttkrpGroup | Family::TtmGroup) => {
                let spec = self.group_cmd().unwrap();
                // the two Eq. 2a/2b products over the COO position space
                let (lhs, rhs) = if family == Family::MttkrpGroup {
                    (
                        Access::new("Y", &["i", "j"]),
                        Expr::Mul(
                            Box::new(Expr::Mul(
                                Box::new(Expr::Access(Access::new("A", &["i", "k", "l"]))),
                                Box::new(Expr::Access(Access::new("X1", &["k", "j"]))),
                            )),
                            Box::new(Expr::Access(Access::new("X2", &["l", "j"]))),
                        ),
                    )
                } else {
                    (
                        Access::new("Y", &["i", "j", "l"]),
                        Expr::Mul(
                            Box::new(Expr::Access(Access::new("A", &["i", "j", "k"]))),
                            Box::new(Expr::Access(Access::new("X1", &["k", "l"]))),
                        ),
                    )
                };
                let producer =
                    Cin::Assign { lhs: Access::new("val", &[]), reduce: false, rhs };
                let consumer = Cin::Assign {
                    lhs,
                    reduce: true,
                    rhs: Expr::Access(Access::new("val", &[])),
                };
                let wh = Cin::Where { consumer: Box::new(consumer), producer: Box::new(producer) };
                let fpos1 = Cin::forall_group("fpos1", spec, OutputRaceStrategy::Atomics, wh);
                let ki = Cin::forall("ki", ParallelUnit::Serial, OutputRaceStrategy::NoRaces, fpos1);
                let ko = Cin::forall("ko", ParallelUnit::GPUWarp, OutputRaceStrategy::NoRaces, ki);
                Cin::forall("block", ParallelUnit::GPUBlock, OutputRaceStrategy::IgnoreRaces, ko)
            }
            Family::FusedSddmmSpmm => {
                let spec = self.group_cmd().unwrap();
                // producer: the SDDMM dot accumulated into the tlaneY
                // scalar workspace over the serial l loop
                let producer = Cin::Assign {
                    lhs: Access::new("tlaneY", &[]),
                    reduce: true,
                    rhs: Expr::Mul(
                        Box::new(Expr::Access(Access::new("X1", &["i", "l"]))),
                        Box::new(Expr::Access(Access::new("X2", &["l", "j"]))),
                    ),
                };
                let l = Cin::forall("l", ParallelUnit::Serial, OutputRaceStrategy::NoRaces, producer);
                // consumer: the SpMM contribution, scaling by A's value and
                // consuming the dot in-register — no Y tensor anywhere
                let consumer = Cin::Assign {
                    lhs: Access::new("C", &["i", "k"]),
                    reduce: true,
                    rhs: Expr::Mul(
                        Box::new(Expr::Mul(
                            Box::new(Expr::Access(Access::new("A", &["i", "j"]))),
                            Box::new(Expr::Access(Access::new("tlaneY", &[]))),
                        )),
                        Box::new(Expr::Access(Access::new("B", &["j", "k"]))),
                    ),
                };
                let wh = Cin::Where { consumer: Box::new(consumer), producer: Box::new(l) };
                let fpos1 = Cin::forall_group("fpos1", spec, OutputRaceStrategy::Atomics, wh);
                let ki = Cin::forall("ki", ParallelUnit::Serial, OutputRaceStrategy::NoRaces, fpos1);
                let warp = Cin::forall("warp", ParallelUnit::GPUWarp, OutputRaceStrategy::NoRaces, ki);
                Cin::forall("block", ParallelUnit::GPUBlock, OutputRaceStrategy::IgnoreRaces, warp)
            }
            Family::RowGroup => {
                let spec = self.group_cmd().unwrap();
                let consumer = Cin::Assign {
                    lhs: Access::new("C", &["i", "k"]),
                    reduce: true,
                    rhs: Expr::Access(Access::new("tjpos1C", &[])),
                };
                let producer = Cin::Assign {
                    lhs: Access::new("tjpos1C", &[]),
                    reduce: true,
                    rhs: mul,
                };
                let jpos0 = Cin::forall("jpos0", ParallelUnit::Serial, OutputRaceStrategy::NoRaces, producer);
                let wh = Cin::Where { consumer: Box::new(consumer), producer: Box::new(jpos0) };
                let jpos1 = Cin::forall_group("jpos1", spec, OutputRaceStrategy::Atomics, wh);
                let kii = Cin::forall("kii", ParallelUnit::GPUThread, OutputRaceStrategy::NoRaces, jpos1);
                let warp = Cin::forall("warp", ParallelUnit::GPUWarp, OutputRaceStrategy::Atomics, kii);
                Cin::forall("ko", ParallelUnit::GPUBlock, OutputRaceStrategy::NoRaces, warp)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classify_four_families() {
        let cfg = SpmmConfig::default();
        assert_eq!(Schedule::taco_nnz_serial(cfg).classify().unwrap(), Family::NnzSerial);
        assert_eq!(Schedule::taco_row_serial(cfg).classify().unwrap(), Family::RowSerial);
        assert_eq!(Schedule::sgap_row_group(cfg, 8).classify().unwrap(), Family::RowGroup);
        assert_eq!(Schedule::sgap_nnz_group(cfg, 16).classify().unwrap(), Family::NnzGroup);
    }

    #[test]
    fn listing5_cin_shape() {
        let s = Schedule::sgap_row_group(SpmmConfig::default(), 8);
        let cin = s.to_cin();
        let txt = cin.to_string();
        // Listing 5 structure: GPUGroup with ParallelReduction on jpos1,
        // where() with the tjpos1C scalar workspace.
        assert!(txt.contains("GPUGroup[8,ParallelReduction]"), "{txt}");
        assert!(txt.contains("where("), "{txt}");
        assert!(txt.contains("tjpos1C+=A(i,j)*B(j,k)"), "{txt}");
        assert_eq!(cin.group_spec().unwrap().size, 8);
    }

    #[test]
    fn listing6_cin_shape() {
        let s = Schedule::sgap_nnz_group(SpmmConfig::default(), 32);
        let txt = s.to_cin().to_string();
        assert!(txt.contains("GPUGroup[32,Segment]"), "{txt}");
        assert!(txt.contains("tmp=A(i,j)*B(j,k)"), "{txt}");
    }

    #[test]
    fn stock_schedules_have_no_group() {
        assert!(Schedule::taco_nnz_serial(SpmmConfig::default()).to_cin().group_spec().is_none());
        assert!(Schedule::taco_row_serial(SpmmConfig::default()).to_cin().group_spec().is_none());
    }

    #[test]
    fn config_validation() {
        let ok = SpmmConfig { n: 16, c: 4, p: 256, g: 32, r: 8, x: 1 };
        ok.validate().unwrap();
        let bad_c = SpmmConfig { n: 4, c: 3, ..ok };
        assert!(bad_c.validate().is_err());
        let bad_r = SpmmConfig { r: 12, ..ok };
        assert!(bad_r.validate().is_err());
    }

    #[test]
    fn cmd_display() {
        let s = Schedule::sgap_row_group(SpmmConfig::default(), 4);
        let rendered: Vec<String> = s.cmds.iter().map(|c| c.to_string()).collect();
        let all = rendered.join(" and ");
        assert!(all.contains("fuse(i,k,io)"));
        assert!(all.contains("pos(j,jpos,A(i,j))"));
        assert!(all.contains("parallelize(jpos1,GPUGroup,4,ParallelReduction,Atomics)"));
    }

    #[test]
    fn sddmm_schedule_classifies_and_plans() {
        let s = Schedule::sddmm_group(SddmmConfig::new(64, 16, 8));
        assert_eq!(s.classify().unwrap(), Family::SddmmGroup);
        let plan = s.reduction_plan().unwrap();
        assert_eq!(plan.group, 8);
        assert_eq!(plan.strategy, Some(ReductionStrategy::ParallelReduction));
        assert_eq!(plan.writeback, Writeback::LaneZeroAtomic);
        let txt = s.to_cin().to_string();
        assert!(txt.contains("GPUGroup[8,ParallelReduction]"), "{txt}");
        assert!(txt.contains("tlaneY+=X1(i,j)*X2(j,k)"), "{txt}");
        assert!(s.spmm_config().is_none());
    }

    #[test]
    fn dgsparse_schedule_classifies_and_plans() {
        let s = Schedule::dgsparse_rb_pr(DgConfig::stock(16));
        assert_eq!(s.classify().unwrap(), Family::DgRowBalanced);
        let plan = s.reduction_plan().unwrap();
        assert_eq!(plan.group, 32);
        assert_eq!(plan.strategy, Some(ReductionStrategy::RowBalancedPartial));
        assert_eq!(plan.writeback, Writeback::LaneZeroAtomic);
        let txt = s.to_cin().to_string();
        assert!(txt.contains("GPUGroup[32,RowBalancedPartial]"), "{txt}");
    }

    #[test]
    fn reduction_plans_of_the_spmm_families() {
        let cfg = SpmmConfig::default();
        let serial_row = Schedule::taco_row_serial(cfg).reduction_plan().unwrap();
        assert_eq!((serial_row.group, serial_row.writeback), (1, Writeback::Store));
        let serial_nnz = Schedule::taco_nnz_serial(cfg).reduction_plan().unwrap();
        assert_eq!((serial_nnz.group, serial_nnz.writeback), (1, Writeback::Atomic));
        let grouped = Schedule::sgap_nnz_group(cfg, 16).reduction_plan().unwrap();
        assert_eq!((grouped.group, grouped.writeback), (16, Writeback::SegmentBoundary));
        let row_grouped = Schedule::sgap_row_group(cfg, 8).reduction_plan().unwrap();
        assert_eq!((row_grouped.group, row_grouped.writeback), (8, Writeback::LaneZeroAtomic));
    }

    #[test]
    fn kernel_config_validates_each_kind() {
        assert!(KernelConfig::Spmm(SpmmConfig::default()).validate().is_ok());
        assert!(KernelConfig::Sddmm(SddmmConfig::new(64, 12, 4)).validate().is_err());
        let mut dg = DgConfig::stock(4);
        dg.group_sz = 12;
        assert!(KernelConfig::Dg(dg).validate().is_err());
        assert!(KernelConfig::Mttkrp(MttkrpConfig::new(8, 4, 16)).validate().is_ok());
        assert!(KernelConfig::Mttkrp(MttkrpConfig::new(8, 3, 16)).validate().is_err());
        assert!(KernelConfig::Ttm(TtmConfig::new(4, 4, 8)).validate().is_ok());
        assert!(KernelConfig::Ttm(TtmConfig::new(4, 4, 12)).validate().is_err());
    }

    #[test]
    fn mttkrp_ttm_schedules_classify_and_plan() {
        let m = Schedule::mttkrp_group(MttkrpConfig::new(8, 4, 16));
        assert_eq!(m.classify().unwrap(), Family::MttkrpGroup);
        let plan = m.reduction_plan().unwrap();
        assert_eq!(plan.group, 16);
        assert_eq!(plan.strategy, Some(ReductionStrategy::SegmentReduction));
        assert_eq!(plan.writeback, Writeback::SegmentBoundary);
        let txt = m.to_cin().to_string();
        assert!(txt.contains("GPUGroup[16,Segment]"), "{txt}");
        assert!(txt.contains("val=A(i,k,l)*X1(k,j)*X2(l,j)"), "{txt}");

        let t = Schedule::ttm_group(TtmConfig::new(4, 4, 8));
        assert_eq!(t.classify().unwrap(), Family::TtmGroup);
        let txt = t.to_cin().to_string();
        assert!(txt.contains("GPUGroup[8,Segment]"), "{txt}");
        assert!(txt.contains("val=A(i,j,k)*X1(k,l)"), "{txt}");
        assert!(txt.contains("Y(i,j,l)+=val"), "{txt}");
    }

    #[test]
    fn coo3_families_reject_non_segment_writebacks() {
        // a lane-zero writeback would drop every segment but the first:
        // classification refuses it with a typed message
        let mut m = Schedule::mttkrp_group(MttkrpConfig::new(8, 4, 16));
        for cmd in &mut m.cmds {
            if let ScheduleCmd::ParallelizeGroup { spec, .. } = cmd {
                spec.strategy = ReductionStrategy::ParallelReduction;
            }
        }
        let err = m.classify().unwrap_err();
        assert!(err.contains("segment-boundary"), "{err}");
    }

    #[test]
    fn every_config_kind_derives_its_algebra() {
        use crate::compiler::expr::TensorAlgebra;
        assert_eq!(Schedule::taco_row_serial(SpmmConfig::default()).algebra(), TensorAlgebra::spmm());
        assert_eq!(Schedule::dgsparse_rb_pr(DgConfig::stock(4)).algebra(), TensorAlgebra::spmm());
        assert_eq!(Schedule::sddmm_group(SddmmConfig::new(16, 8, 4)).algebra(), TensorAlgebra::sddmm());
        assert_eq!(Schedule::mttkrp_group(MttkrpConfig::new(8, 4, 8)).algebra(), TensorAlgebra::mttkrp());
        assert_eq!(Schedule::ttm_group(TtmConfig::new(4, 4, 4)).algebra(), TensorAlgebra::ttm());
        assert_eq!(
            Schedule::fused_sddmm_spmm(FusedConfig::new(32, 4, 4, 16)).algebra(),
            TensorAlgebra::fused_sddmm_spmm()
        );
    }

    #[test]
    fn fused_schedule_classifies_plans_and_has_no_intermediate() {
        let s = Schedule::fused_sddmm_spmm(FusedConfig::new(32, 4, 4, 16));
        assert_eq!(s.classify().unwrap(), Family::FusedSddmmSpmm);
        let plan = s.reduction_plan().unwrap();
        assert_eq!(plan.group, 16);
        assert_eq!(plan.strategy, Some(ReductionStrategy::SegmentReduction));
        assert_eq!(plan.writeback, Writeback::SegmentBoundary);
        let txt = s.to_cin().to_string();
        assert!(txt.contains("GPUGroup[16,Segment]"), "{txt}");
        assert!(txt.contains("tlaneY+=X1(i,l)*X2(l,j)"), "{txt}");
        assert!(txt.contains("C(i,k)+=A(i,j)*tlaneY*B(j,k)"), "{txt}");
        // the whole point: no materialized Y anywhere in the fused CIN
        assert!(!txt.contains("Y("), "{txt}");
        // a non-segment writeback would drop all but the first segment
        let mut bad = s.clone();
        for cmd in &mut bad.cmds {
            if let ScheduleCmd::ParallelizeGroup { spec, .. } = cmd {
                spec.strategy = ReductionStrategy::ParallelReduction;
            }
        }
        let err = bad.classify().unwrap_err();
        assert!(err.contains("segment-boundary"), "{err}");
    }

    #[test]
    fn fused_config_validates_launch_shape() {
        assert!(FusedConfig::new(32, 4, 4, 16).validate().is_ok());
        // c must divide N
        assert!(FusedConfig::new(32, 4, 3, 16).validate().is_err());
        // the dot needs at least one term
        assert!(FusedConfig::new(0, 4, 4, 16).validate().is_err());
        // r wider than the contiguous nnz lanes per block
        assert!(FusedConfig::new(32, 64, 1, 8).validate().is_err());
        assert_eq!(FusedConfig::new(32, 4, 4, 16).npb(), 256);
        assert_eq!(FusedConfig::new(32, 4, 1, 16).npb(), 64);
    }

    #[test]
    fn roots_trace_derived_vars_to_source_dims() {
        let v = IndexVar::new;
        // Listing 5: jpos1 ← jpos ← j (the reduction dim)
        let s = Schedule::sgap_row_group(SpmmConfig::default(), 8);
        assert_eq!(s.roots_of(&v("jpos1")), vec![v("j")]);
        // Listing 6: fpos1 ← fpos ← f ← fuse(i, j)
        let s = Schedule::sgap_nnz_group(SpmmConfig::default(), 8);
        let roots = s.roots_of(&v("fpos1"));
        assert!(roots.contains(&v("i")) && roots.contains(&v("j")), "{roots:?}");
        // MTTKRP: fpos1 ← f ← fuse(fuse(i, k), l)
        let s = Schedule::mttkrp_group(MttkrpConfig::new(8, 4, 16));
        let roots = s.roots_of(&v("fpos1"));
        assert_eq!(roots.len(), 3, "{roots:?}");
        for d in ["i", "k", "l"] {
            assert!(roots.contains(&v(d)), "{roots:?} missing {d}");
        }
        // a var that is never derived roots to itself
        assert_eq!(s.roots_of(&v("zz")), vec![v("zz")]);
        // group_binding exposes the scheduled var + spec
        let (var, spec) = s.group_binding().unwrap();
        assert_eq!(var, v("fpos1"));
        assert_eq!(spec.size, 16);
    }
}
