//! Schedule commands — the TACO scheduling language plus the Sgap
//! extension (§5.1): `parallelize` now accepts `GPUGroup` with a
//! [`GroupSpec`], and `GPUWarp` keeps only tiling semantics.
//!
//! A [`Schedule`] is an ordered command list applied to a tensor algebra
//! statement. [`Schedule::to_cin`] produces the concrete index notation
//! (the paper's Listings 3–6); [`Schedule::classify`] recognizes which of
//! the four SpMM algorithm families the command list describes so the
//! lowerer can emit the corresponding LLIR.

use std::fmt;

use super::cin::{Cin, GroupSpec, OutputRaceStrategy, ParallelUnit, ReductionStrategy};
use super::expr::{Access, Expr, IndexVar};

/// One scheduling command (subset of TACO's API used by the paper).
#[derive(Debug, Clone, PartialEq)]
pub enum ScheduleCmd {
    /// `fuse(i, j, f)` — fuse two index vars into one.
    Fuse { a: IndexVar, b: IndexVar, into: IndexVar },
    /// `pos(f, fpos, A(i,j))` — move to position space of a tensor level.
    Pos { var: IndexVar, pos_var: IndexVar, access: Access },
    /// `split(v, outer, inner, factor)`.
    Split { var: IndexVar, outer: IndexVar, inner: IndexVar, factor: u32 },
    /// `bound(v, bv, extent, MaxExact)`.
    Bound { var: IndexVar, bound_var: IndexVar, extent: u32 },
    /// `reorder(vars...)`.
    Reorder { order: Vec<IndexVar> },
    /// `precompute(expr, v, workspace)` — scalar workspace (§5.3).
    Precompute { workspace: String },
    /// `parallelize(v, unit, race)` — stock TACO form.
    Parallelize { var: IndexVar, unit: ParallelUnit, race: OutputRaceStrategy },
    /// `parallelize(v, GPUGroup, r, strategy)` — the Sgap form.
    ParallelizeGroup { var: IndexVar, spec: GroupSpec, race: OutputRaceStrategy },
}

impl fmt::Display for ScheduleCmd {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ScheduleCmd::Fuse { a, b, into } => write!(f, "fuse({a},{b},{into})"),
            ScheduleCmd::Pos { var, pos_var, access } => write!(f, "pos({var},{pos_var},{access})"),
            ScheduleCmd::Split { var, outer, inner, factor } => {
                write!(f, "split({var},{outer},{inner},{factor})")
            }
            ScheduleCmd::Bound { var, bound_var, extent } => {
                write!(f, "bound({var},{bound_var},{extent},MaxExact)")
            }
            ScheduleCmd::Reorder { order } => {
                let s: Vec<String> = order.iter().map(|v| v.to_string()).collect();
                write!(f, "reorder({})", s.join(","))
            }
            ScheduleCmd::Precompute { workspace } => write!(f, "precompute({workspace})"),
            ScheduleCmd::Parallelize { var, unit, race } => {
                write!(f, "parallelize({var},{unit},{race})")
            }
            ScheduleCmd::ParallelizeGroup { var, spec, race } => {
                write!(f, "parallelize({var},GPUGroup,{},{},{race})", spec.size, spec.strategy)
            }
        }
    }
}

/// Tunable parameters shared by all four SpMM schedules.
///
/// `n` = dense columns, `c` = coarsening (cols per thread), `p` = threads
/// per block, `g` = the data granularity (nnz per thread, or threads per
/// row), `r` = reduction parallelism (GroupSize), `x` = rows per thread.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpmmConfig {
    pub n: u32,
    pub c: u32,
    pub p: u32,
    pub g: u32,
    pub r: u32,
    pub x: u32,
}

impl Default for SpmmConfig {
    fn default() -> Self {
        SpmmConfig { n: 4, c: 4, p: 256, g: 32, r: 32, x: 1 }
    }
}

impl SpmmConfig {
    /// Column-chunks per row tile: how many thread-columns cover N.
    /// (Callers must `validate()` first; a non-dividing `c` is reported
    /// there, not here.)
    pub fn kchunks(&self) -> u32 {
        (self.n / self.c).max(1)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.n % self.c != 0 {
            return Err(format!("c={} must divide N={}", self.c, self.n));
        }
        if !self.r.is_power_of_two() || self.r > 32 {
            return Err(format!("r={} must be a power of 2 <= 32", self.r));
        }
        if !self.g.is_power_of_two() && self.g != self.p {
            // g is a thread-grouping factor in row-group schedules
        }
        if self.p % self.kchunks() != 0 {
            return Err(format!("p={} must be divisible by N/c={}", self.p, self.kchunks()));
        }
        Ok(())
    }
}

/// The four SpMM algorithm families of §6, identified from a command list.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    /// `{<g nnz, c col>, 1}` — Listing 3 (EB + serial reduction).
    NnzSerial,
    /// `{<x row, c col>, 1}` — Listing 4 (RB + serial reduction).
    RowSerial,
    /// `{<1/g row, c col>, r}` — Listing 5 (RB + grouped parallel reduction).
    RowGroup,
    /// `{<1 nnz, c col>, r}` — Listing 6 (EB + grouped segment reduction).
    NnzGroup,
}

/// A complete schedule: the commands plus resolved tuning parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct Schedule {
    pub cmds: Vec<ScheduleCmd>,
    pub config: SpmmConfig,
}

impl Schedule {
    // ---- the four canonical schedules (Listings 3–6) --------------------

    /// Listing 3: `{<g nnz, c col>, 1}` — original TACO nnz-split.
    pub fn taco_nnz_serial(config: SpmmConfig) -> Schedule {
        let v = |s: &str| IndexVar::new(s);
        let nnz_per_block = config.g * (config.p / config.kchunks());
        Schedule {
            cmds: vec![
                ScheduleCmd::Fuse { a: v("i"), b: v("j"), into: v("f") },
                ScheduleCmd::Pos { var: v("f"), pos_var: v("fpos"), access: Access::new("A", &["i", "j"]) },
                ScheduleCmd::Split { var: v("fpos"), outer: v("block"), inner: v("fpos1"), factor: nnz_per_block },
                ScheduleCmd::Split { var: v("fpos1"), outer: v("warp"), inner: v("fpos2"), factor: config.g },
                ScheduleCmd::Split { var: v("k"), outer: v("ko"), inner: v("ki"), factor: config.c },
                ScheduleCmd::Bound { var: v("ko"), bound_var: v("ko"), extent: config.kchunks() },
                ScheduleCmd::Precompute { workspace: "tmp".into() },
                ScheduleCmd::Parallelize { var: v("block"), unit: ParallelUnit::GPUBlock, race: OutputRaceStrategy::IgnoreRaces },
                ScheduleCmd::Parallelize { var: v("warp"), unit: ParallelUnit::GPUWarp, race: OutputRaceStrategy::NoRaces },
                ScheduleCmd::Parallelize { var: v("fpos2"), unit: ParallelUnit::GPUThread, race: OutputRaceStrategy::Atomics },
            ],
            config,
        }
    }

    /// Listing 4: `{<x row, c col>, 1}` — original TACO row-split.
    pub fn taco_row_serial(config: SpmmConfig) -> Schedule {
        let v = |s: &str| IndexVar::new(s);
        let rows_per_block = config.x * config.p / config.kchunks();
        Schedule {
            cmds: vec![
                ScheduleCmd::Split { var: v("i"), outer: v("block"), inner: v("io"), factor: rows_per_block },
                ScheduleCmd::Split { var: v("io"), outer: v("warp"), inner: v("ii"), factor: config.x },
                ScheduleCmd::Split { var: v("k"), outer: v("ko"), inner: v("ki"), factor: config.c },
                ScheduleCmd::Bound { var: v("ko"), bound_var: v("ko"), extent: config.kchunks() },
                ScheduleCmd::Parallelize { var: v("block"), unit: ParallelUnit::GPUBlock, race: OutputRaceStrategy::NoRaces },
                ScheduleCmd::Parallelize { var: v("ii"), unit: ParallelUnit::GPUThread, race: OutputRaceStrategy::NoRaces },
            ],
            config,
        }
    }

    /// Listing 5: `{<1/g row, c col>, r}` — Sgap row-split with grouped
    /// parallel reduction (`atomicAddGroup<float, r>`).
    pub fn sgap_row_group(config: SpmmConfig, r: u32) -> Schedule {
        let v = |s: &str| IndexVar::new(s);
        let mut config = config;
        config.r = r;
        Schedule {
            cmds: vec![
                ScheduleCmd::Fuse { a: v("i"), b: v("k"), into: v("io") },
                ScheduleCmd::Split { var: v("io"), outer: v("ko"), inner: v("ki"), factor: config.c * config.p / config.g },
                ScheduleCmd::Split { var: v("ki"), outer: v("warp"), inner: v("kii"), factor: config.c },
                ScheduleCmd::Pos { var: v("j"), pos_var: v("jpos"), access: Access::new("A", &["i", "j"]) },
                ScheduleCmd::Split { var: v("jpos"), outer: v("jpos0"), inner: v("jpos1"), factor: config.g },
                ScheduleCmd::Parallelize { var: v("ko"), unit: ParallelUnit::GPUBlock, race: OutputRaceStrategy::NoRaces },
                ScheduleCmd::Parallelize { var: v("warp"), unit: ParallelUnit::GPUWarp, race: OutputRaceStrategy::Atomics },
                ScheduleCmd::ParallelizeGroup {
                    var: v("jpos1"),
                    spec: GroupSpec::new(r, ReductionStrategy::ParallelReduction),
                    race: OutputRaceStrategy::Atomics,
                },
            ],
            config,
        }
    }

    /// Listing 6: `{<1 nnz, c col>, r}` — Sgap nnz-split with grouped
    /// segment reduction (`segReduceGroup<float, r>`).
    pub fn sgap_nnz_group(config: SpmmConfig, r: u32) -> Schedule {
        let v = |s: &str| IndexVar::new(s);
        let mut config = config;
        config.r = r;
        let nnz_per_block = config.p / config.kchunks();
        Schedule {
            cmds: vec![
                ScheduleCmd::Fuse { a: v("i"), b: v("j"), into: v("f") },
                ScheduleCmd::Pos { var: v("f"), pos_var: v("fpos"), access: Access::new("A", &["i", "j"]) },
                ScheduleCmd::Split { var: v("fpos"), outer: v("block"), inner: v("fpos1"), factor: nnz_per_block },
                ScheduleCmd::Split { var: v("k"), outer: v("ko"), inner: v("ki"), factor: config.c },
                ScheduleCmd::Bound { var: v("ko"), bound_var: v("warp"), extent: config.kchunks() },
                ScheduleCmd::Precompute { workspace: "tmp".into() },
                ScheduleCmd::Parallelize { var: v("block"), unit: ParallelUnit::GPUBlock, race: OutputRaceStrategy::IgnoreRaces },
                ScheduleCmd::Parallelize { var: v("warp"), unit: ParallelUnit::GPUWarp, race: OutputRaceStrategy::NoRaces },
                ScheduleCmd::Parallelize { var: v("fpos1"), unit: ParallelUnit::GPUThread, race: OutputRaceStrategy::Atomics },
                ScheduleCmd::ParallelizeGroup {
                    var: v("fpos1"),
                    spec: GroupSpec::new(r, ReductionStrategy::SegmentReduction),
                    race: OutputRaceStrategy::Atomics,
                },
            ],
            config,
        }
    }

    // ---- analysis --------------------------------------------------------

    /// Identify which algorithm family the command list describes.
    ///
    /// Stock TACO (before Sgap) rejects anything with `GPUGroup`; here it
    /// is a first-class citizen. Unrecognized command shapes are an error
    /// — the lowerer supports exactly the shapes the paper exercises.
    pub fn classify(&self) -> Result<Family, String> {
        let has_pos = self.cmds.iter().any(|c| matches!(c, ScheduleCmd::Pos { .. }));
        let group = self.group_cmd();
        match (has_pos, group) {
            (true, Some(spec)) => match spec.strategy {
                ReductionStrategy::SegmentReduction => Ok(Family::NnzGroup),
                ReductionStrategy::ParallelReduction => Ok(Family::RowGroup),
            },
            (true, None) => {
                // pos without a group: nnz-split serial (Listing 3) unless the
                // pos var is the reduction var split for cooperative rows.
                let fused_ij = self.cmds.iter().any(|c| matches!(c, ScheduleCmd::Fuse { a, b, .. } if a.0 == "i" && b.0 == "j"));
                if fused_ij {
                    Ok(Family::NnzSerial)
                } else {
                    Err("pos-schedule without (i,j) fusion or GPUGroup is unsupported".into())
                }
            }
            (false, None) => Ok(Family::RowSerial),
            (false, Some(_)) => Err("GPUGroup requires a pos() schedule".into()),
        }
    }

    fn group_cmd(&self) -> Option<GroupSpec> {
        self.cmds.iter().find_map(|c| match c {
            ScheduleCmd::ParallelizeGroup { spec, .. } => Some(*spec),
            _ => None,
        })
    }

    /// Build the concrete index notation (Listings 3–6 shapes).
    pub fn to_cin(&self) -> Cin {
        let mul = Expr::Mul(
            Box::new(Expr::Access(Access::new("A", &["i", "j"]))),
            Box::new(Expr::Access(Access::new("B", &["j", "k"]))),
        );
        match self.classify().expect("unsupported schedule") {
            Family::NnzSerial | Family::NnzGroup => {
                let strategy = self.group_cmd();
                let consumer = Cin::Assign {
                    lhs: Access::new("C", &["i", "k"]),
                    reduce: true,
                    rhs: Expr::Access(Access::new("tmp", &[])),
                };
                let producer = Cin::Assign {
                    lhs: Access::new("tmp", &[]),
                    reduce: strategy.is_none(), // serial family accumulates into tmp
                    rhs: mul,
                };
                let wh = Cin::Where { consumer: Box::new(consumer), producer: Box::new(producer) };
                let inner = match strategy {
                    Some(spec) => Cin::forall_group("fpos1", spec, OutputRaceStrategy::Atomics, wh),
                    None => Cin::forall("fpos2", ParallelUnit::GPUThread, OutputRaceStrategy::Atomics, wh),
                };
                let ki = Cin::forall("ki", ParallelUnit::Serial, OutputRaceStrategy::NoRaces, inner);
                let warp = Cin::forall("warp", ParallelUnit::GPUWarp, OutputRaceStrategy::NoRaces, ki);
                Cin::forall("block", ParallelUnit::GPUBlock, OutputRaceStrategy::IgnoreRaces, warp)
            }
            Family::RowSerial => {
                let asn = Cin::Assign { lhs: Access::new("C", &["i", "k"]), reduce: true, rhs: mul };
                let j = Cin::forall("j", ParallelUnit::Serial, OutputRaceStrategy::NoRaces, asn);
                let ki = Cin::forall("ki", ParallelUnit::Serial, OutputRaceStrategy::NoRaces, j);
                let ii = Cin::forall("ii", ParallelUnit::GPUThread, OutputRaceStrategy::NoRaces, ki);
                Cin::forall("block", ParallelUnit::GPUBlock, OutputRaceStrategy::NoRaces, ii)
            }
            Family::RowGroup => {
                let spec = self.group_cmd().unwrap();
                let consumer = Cin::Assign {
                    lhs: Access::new("C", &["i", "k"]),
                    reduce: true,
                    rhs: Expr::Access(Access::new("tjpos1C", &[])),
                };
                let producer = Cin::Assign {
                    lhs: Access::new("tjpos1C", &[]),
                    reduce: true,
                    rhs: mul,
                };
                let jpos0 = Cin::forall("jpos0", ParallelUnit::Serial, OutputRaceStrategy::NoRaces, producer);
                let wh = Cin::Where { consumer: Box::new(consumer), producer: Box::new(jpos0) };
                let jpos1 = Cin::forall_group("jpos1", spec, OutputRaceStrategy::Atomics, wh);
                let kii = Cin::forall("kii", ParallelUnit::GPUThread, OutputRaceStrategy::NoRaces, jpos1);
                let warp = Cin::forall("warp", ParallelUnit::GPUWarp, OutputRaceStrategy::Atomics, kii);
                Cin::forall("ko", ParallelUnit::GPUBlock, OutputRaceStrategy::NoRaces, warp)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classify_four_families() {
        let cfg = SpmmConfig::default();
        assert_eq!(Schedule::taco_nnz_serial(cfg).classify().unwrap(), Family::NnzSerial);
        assert_eq!(Schedule::taco_row_serial(cfg).classify().unwrap(), Family::RowSerial);
        assert_eq!(Schedule::sgap_row_group(cfg, 8).classify().unwrap(), Family::RowGroup);
        assert_eq!(Schedule::sgap_nnz_group(cfg, 16).classify().unwrap(), Family::NnzGroup);
    }

    #[test]
    fn listing5_cin_shape() {
        let s = Schedule::sgap_row_group(SpmmConfig::default(), 8);
        let cin = s.to_cin();
        let txt = cin.to_string();
        // Listing 5 structure: GPUGroup with ParallelReduction on jpos1,
        // where() with the tjpos1C scalar workspace.
        assert!(txt.contains("GPUGroup[8,ParallelReduction]"), "{txt}");
        assert!(txt.contains("where("), "{txt}");
        assert!(txt.contains("tjpos1C+=A(i,j)*B(j,k)"), "{txt}");
        assert_eq!(cin.group_spec().unwrap().size, 8);
    }

    #[test]
    fn listing6_cin_shape() {
        let s = Schedule::sgap_nnz_group(SpmmConfig::default(), 32);
        let txt = s.to_cin().to_string();
        assert!(txt.contains("GPUGroup[32,Segment]"), "{txt}");
        assert!(txt.contains("tmp=A(i,j)*B(j,k)"), "{txt}");
    }

    #[test]
    fn stock_schedules_have_no_group() {
        assert!(Schedule::taco_nnz_serial(SpmmConfig::default()).to_cin().group_spec().is_none());
        assert!(Schedule::taco_row_serial(SpmmConfig::default()).to_cin().group_spec().is_none());
    }

    #[test]
    fn config_validation() {
        let ok = SpmmConfig { n: 16, c: 4, p: 256, g: 32, r: 8, x: 1 };
        ok.validate().unwrap();
        let bad_c = SpmmConfig { n: 4, c: 3, ..ok };
        assert!(bad_c.validate().is_err());
        let bad_r = SpmmConfig { r: 12, ..ok };
        assert!(bad_r.validate().is_err());
    }

    #[test]
    fn cmd_display() {
        let s = Schedule::sgap_row_group(SpmmConfig::default(), 4);
        let rendered: Vec<String> = s.cmds.iter().map(|c| c.to_string()).collect();
        let all = rendered.join(" and ");
        assert!(all.contains("fuse(i,k,io)"));
        assert!(all.contains("pos(j,jpos,A(i,j))"));
        assert!(all.contains("parallelize(jpos1,GPUGroup,4,ParallelReduction,Atomics)"));
    }
}
