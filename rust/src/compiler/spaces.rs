//! Atomic parallelism — the SpMM optimization-space model (§3, Fig. 7/8).
//!
//! A point is `{<x D, y col>, r}` with `D ∈ {nnz, row}`,
//! `x, y ∈ {1/g, 1, g}` (minimal data) and reduction parallelism
//! `r ∈ {1, 2, 4, 8, 16, 32}`. Three pruning rules (§3.3) define legality;
//! [`enumerate_legal`] walks the whole space, and
//! [`AtomicPoint::da_spmm_embedding`] reproduces the paper's claim that
//! DA-SpMM's 8-algorithm space embeds into atomic parallelism.

use std::fmt;

/// What a thread's minimal datum is along the sparse axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DataKind {
    Nnz,
    Row,
}

impl fmt::Display for DataKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", if *self == DataKind::Nnz { "nnz" } else { "row" })
    }
}

/// The `x`/`y` multiplier of a minimal datum: `1/g`, `1`, or `g` — with
/// `g > 1` tunable. `Inv(g)` means `g` threads share one datum.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Factor {
    /// `1/g` — g threads cooperate on one datum.
    Inv(u32),
    /// exactly one datum per thread.
    One,
    /// `g` data per thread.
    Times(u32),
}

impl Factor {
    pub fn validate(self) -> Result<(), String> {
        match self {
            Factor::Inv(g) | Factor::Times(g) if g < 2 => {
                Err(format!("tunable factor must be >= 2, got {g} (use One for 1)"))
            }
            _ => Ok(()),
        }
    }
}

impl fmt::Display for Factor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Factor::Inv(g) => write!(f, "1/{g}"),
            Factor::One => write!(f, "1"),
            Factor::Times(g) => write!(f, "{g}"),
        }
    }
}

/// A point in the atomic-parallelism space: `{<x D, y col>, r}`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct AtomicPoint {
    pub kind: DataKind,
    pub x: Factor,
    pub col: Factor,
    pub r: u32,
}

/// Why a point is illegal (§3.3's three rules).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Illegality {
    /// Rule 1: `<1/g nnz, ...>` or `<x nnz, 1/c col>` — a non-zero must be
    /// multiplied by at least one dense element.
    Rule1FractionalNnzOrCol,
    /// Rule 2: `<1/g row, x col>` with `r < g` — an r-wide parallel
    /// reduction cannot cover the g cooperating threads' partials with a
    /// single writeback thread.
    Rule2ParallelReductionWriteback,
    /// Rule 3: `<1/g row, 1/c col>` — resource parallelism may multiply
    /// only one element of the atomic parallelism.
    Rule3DoubleFraction,
    /// r out of the hardware range {1,2,4,8,16,32}.
    BadReductionParallelism,
}

impl AtomicPoint {
    pub fn new(kind: DataKind, x: Factor, col: Factor, r: u32) -> Self {
        AtomicPoint { kind, x, col, r }
    }

    /// Check the point against the three §3.3 rules. `Ok(())` = legal.
    pub fn legality(&self) -> Result<(), Illegality> {
        if !(self.r == 1 || (self.r.is_power_of_two() && self.r <= 32)) {
            return Err(Illegality::BadReductionParallelism);
        }
        match (self.kind, self.x, self.col) {
            // Rule 1: fractional nnz, or nnz with fractional col
            (DataKind::Nnz, Factor::Inv(_), _) => Err(Illegality::Rule1FractionalNnzOrCol),
            (DataKind::Nnz, _, Factor::Inv(_)) => Err(Illegality::Rule1FractionalNnzOrCol),
            // Rule 3: both axes fractional
            (DataKind::Row, Factor::Inv(_), Factor::Inv(_)) => Err(Illegality::Rule3DoubleFraction),
            // Rule 2: cooperative rows need r >= g for parallel reduction
            (DataKind::Row, Factor::Inv(g), _) if self.r < g => {
                Err(Illegality::Rule2ParallelReductionWriteback)
            }
            _ => Ok(()),
        }
    }

    pub fn is_legal(&self) -> bool {
        self.legality().is_ok()
    }

    /// Legality when the output race strategy is `Atomics`: Rule 2 is
    /// lifted, because each r-wide subgroup may write back atomically
    /// (multiple writeback threads per cooperating row group). This is
    /// exactly the configuration Table 1 evaluates (`g = 32, r ∈ {4, 8}`)
    /// — the paper states Rule 2 for the single-writeback parallel
    /// reduction only.
    pub fn legality_with_atomics(&self) -> Result<(), Illegality> {
        match self.legality() {
            Err(Illegality::Rule2ParallelReductionWriteback) => Ok(()),
            other => other,
        }
    }

    pub fn is_legal_with_atomics(&self) -> bool {
        self.legality_with_atomics().is_ok()
    }

    // ---- the DA-SpMM embedding (§3.3) ------------------------------------

    /// `EB+PR` = `{<1 nnz, c col>, 32}`.
    pub fn eb_pr(c: u32) -> Self {
        AtomicPoint::new(DataKind::Nnz, Factor::One, Factor::Times(c), 32)
    }
    /// `RB+PR` = `{<1/32 row, c col>, 32}`.
    pub fn rb_pr(c: u32) -> Self {
        AtomicPoint::new(DataKind::Row, Factor::Inv(32), Factor::Times(c), 32)
    }
    /// `EB+SR` = `{<32 nnz, c col>, 1}`.
    pub fn eb_sr(c: u32) -> Self {
        AtomicPoint::new(DataKind::Nnz, Factor::Times(32), Factor::Times(c), 1)
    }
    /// `RB+SR` = `{<1 row, c col>, 1}`.
    pub fn rb_sr(c: u32) -> Self {
        AtomicPoint::new(DataKind::Row, Factor::One, Factor::Times(c), 1)
    }

    /// All four DA-SpMM algorithm classes (row-major half of the 8; the
    /// paper folds RM/CM into implementation detail).
    pub fn da_spmm_embedding(c: u32) -> Vec<(&'static str, AtomicPoint)> {
        vec![
            ("EB+PR", Self::eb_pr(c)),
            ("RB+PR", Self::rb_pr(c)),
            ("EB+SR", Self::eb_sr(c)),
            ("RB+SR", Self::rb_sr(c)),
        ]
    }

    /// The two new Sgap algorithms (§6.2).
    pub fn sgap_row(g: u32, c: u32, r: u32) -> Self {
        AtomicPoint::new(DataKind::Row, Factor::Inv(g), Factor::Times(c), r)
    }
    pub fn sgap_nnz(c: u32, r: u32) -> Self {
        AtomicPoint::new(DataKind::Nnz, Factor::One, Factor::Times(c), r)
    }

    /// dgSPARSE's RB+PR kernel as an atomic-parallelism point:
    /// `{<1/workerSz row, coarsenSz col>, groupSz}` — `workerSz` lanes
    /// cooperate per row, each covering `coarsenSz` dense columns, with a
    /// `groupSz`-wide parallel reduction. Legal under the Atomics race
    /// strategy (Rule 2 lifted), which is how the library writes back.
    pub fn dg_rb_pr(worker_sz: u32, coarsen_sz: u32, group_sz: u32) -> Self {
        let x = if worker_sz > 1 { Factor::Inv(worker_sz) } else { Factor::One };
        let col = if coarsen_sz > 1 { Factor::Times(coarsen_sz) } else { Factor::One };
        AtomicPoint::new(DataKind::Row, x, col, group_sz)
    }
}

impl fmt::Display for AtomicPoint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{{<{} {}, {} col>, {}}}", self.x, self.kind, self.col, self.r)
    }
}

/// Enumerate every point over the given tunable values and classify it —
/// the data behind Fig. 7/8.
pub fn enumerate_all(gs: &[u32], cs: &[u32], rs: &[u32]) -> Vec<(AtomicPoint, Result<(), Illegality>)> {
    let mut out = Vec::new();
    let factors = |vals: &[u32]| {
        let mut f = vec![Factor::One];
        for &v in vals {
            f.push(Factor::Inv(v));
            f.push(Factor::Times(v));
        }
        f
    };
    for kind in [DataKind::Nnz, DataKind::Row] {
        for &x in &factors(gs) {
            for &col in &factors(cs) {
                for &r in rs {
                    let p = AtomicPoint::new(kind, x, col, r);
                    let l = p.legality();
                    out.push((p, l));
                }
            }
        }
    }
    out
}

/// Only the legal points.
pub fn enumerate_legal(gs: &[u32], cs: &[u32], rs: &[u32]) -> Vec<AtomicPoint> {
    enumerate_all(gs, cs, rs).into_iter().filter(|(_, l)| l.is_ok()).map(|(p, _)| p).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rule1_fractional_nnz_illegal() {
        let p = AtomicPoint::new(DataKind::Nnz, Factor::Inv(4), Factor::One, 32);
        assert_eq!(p.legality(), Err(Illegality::Rule1FractionalNnzOrCol));
        let q = AtomicPoint::new(DataKind::Nnz, Factor::Times(4), Factor::Inv(2), 32);
        assert_eq!(q.legality(), Err(Illegality::Rule1FractionalNnzOrCol));
    }

    #[test]
    fn rule2_row_fraction_needs_r_ge_g() {
        let bad = AtomicPoint::new(DataKind::Row, Factor::Inv(32), Factor::One, 8);
        assert_eq!(bad.legality(), Err(Illegality::Rule2ParallelReductionWriteback));
        let ok = AtomicPoint::new(DataKind::Row, Factor::Inv(8), Factor::One, 8);
        assert!(ok.is_legal());
        let ok2 = AtomicPoint::new(DataKind::Row, Factor::Inv(8), Factor::One, 32);
        assert!(ok2.is_legal());
    }

    #[test]
    fn rule3_double_fraction_illegal() {
        let p = AtomicPoint::new(DataKind::Row, Factor::Inv(4), Factor::Inv(2), 32);
        assert_eq!(p.legality(), Err(Illegality::Rule3DoubleFraction));
    }

    #[test]
    fn da_spmm_points_are_legal_and_as_published() {
        for (name, p) in AtomicPoint::da_spmm_embedding(4) {
            assert!(p.is_legal(), "{name} {p} illegal");
        }
        assert_eq!(AtomicPoint::eb_pr(4).to_string(), "{<1 nnz, 4 col>, 32}");
        assert_eq!(AtomicPoint::rb_pr(4).to_string(), "{<1/32 row, 4 col>, 32}");
        assert_eq!(AtomicPoint::eb_sr(4).to_string(), "{<32 nnz, 4 col>, 1}");
        assert_eq!(AtomicPoint::rb_sr(4).to_string(), "{<1 row, 4 col>, 1}");
    }

    #[test]
    fn sgap_points_extend_da_spmm() {
        // {<1 nnz, c col>, r} with r < 32 is legal but NOT in DA-SpMM
        let p = AtomicPoint::sgap_nnz(4, 8);
        assert!(p.is_legal());
        for (_, q) in AtomicPoint::da_spmm_embedding(4) {
            assert_ne!(p, q);
        }
    }

    #[test]
    fn enumeration_counts() {
        let all = enumerate_all(&[8, 32], &[4], &[1, 8, 32]);
        // factors: One, Inv8, T8, Inv32, T32 (5) × col: One, Inv4, T4 (3)
        // × kinds 2 × r 3 = 90
        assert_eq!(all.len(), 90);
        let legal = enumerate_legal(&[8, 32], &[4], &[1, 8, 32]);
        assert!(!legal.is_empty() && legal.len() < all.len());
        for p in &legal {
            assert!(p.is_legal());
        }
    }

    #[test]
    fn dg_rb_pr_point_legal_under_atomics() {
        // stock dgSPARSE: 32 lanes/row, coarsen 4, group 32 → Rule 2 holds
        assert!(AtomicPoint::dg_rb_pr(32, 4, 32).is_legal());
        // tuned groupSz < workerSz needs the Atomics lift (Rule 2)
        let tuned = AtomicPoint::dg_rb_pr(32, 4, 8);
        assert_eq!(tuned.legality(), Err(Illegality::Rule2ParallelReductionWriteback));
        assert!(tuned.is_legal_with_atomics());
        // degenerate factors collapse to One instead of Inv(1)/Times(1)
        let p = AtomicPoint::dg_rb_pr(1, 1, 1);
        assert_eq!((p.x, p.col), (Factor::One, Factor::One));
    }

    #[test]
    fn bad_r_rejected() {
        let p = AtomicPoint::new(DataKind::Nnz, Factor::One, Factor::One, 12);
        assert_eq!(p.legality(), Err(Illegality::BadReductionParallelism));
        let q = AtomicPoint::new(DataKind::Nnz, Factor::One, Factor::One, 64);
        assert_eq!(q.legality(), Err(Illegality::BadReductionParallelism));
    }
}
