//! Minimal JSON parser — enough for `artifacts/manifest.json`.
//!
//! In-tree because the offline dependency set has no serde; supports the
//! full JSON grammar except `\u` surrogate pairs (not emitted by aot.py).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing content"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.into(), pos: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{lit}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| self.err("bad \\u"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u"))?;
                            out.push(char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?);
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let s = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let src = r#"{
            "gcn2": {"kind": "gcn2", "rows": 4096, "n": 16,
                     "args": [[[16384], "int32"], [[4096, 64], "float32"]]},
            "x": [1, -2.5, 1e3, true, false, null, "s\n"]
        }"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("gcn2").unwrap().get("rows").unwrap().as_usize(), Some(4096));
        let args = j.get("gcn2").unwrap().get("args").unwrap().as_arr().unwrap();
        assert_eq!(args[0].as_arr().unwrap()[1].as_str(), Some("int32"));
        let x = j.get("x").unwrap().as_arr().unwrap();
        assert_eq!(x[1].as_f64(), Some(-2.5));
        assert_eq!(x[2].as_f64(), Some(1000.0));
        assert_eq!(x[6].as_str(), Some("s\n"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\": 1} x").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap().as_str(), Some("A"));
    }
}
