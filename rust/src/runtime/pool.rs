//! Device-buffer pool: exclusive size-class pages keyed by operand
//! fingerprint, so resubmitting a registered handle reuses its staged
//! device image instead of rebuilding padded buffers and re-uploading.
//!
//! This build has no physical accelerator — the "device image"
//! ([`DeviceImage`]) is the marshalled buffer set an executor would copy
//! to one: padded COO/ELL for PJRT artifacts, raw CSR/COO-3/dense views
//! for the simulator. What the pool makes real is the *policy* layer a
//! device allocator needs either way:
//!
//! * **Exclusive pages** — one image per page (never sub-allocated), in
//!   power-of-two size classes so a reuse never depends on exact byte
//!   matches.
//! * **Fingerprint keying** — a [`PoolKey`] pairs the handle's
//!   never-reused registration uid with a sampled content fingerprint,
//!   so a stale image cannot be resurrected by id recycling.
//! * **LRU reclamation under a byte budget** — free (unreferenced)
//!   pages are evicted oldest-first whenever residency exceeds the
//!   budget; pages with live [`PoolRef`]s are never evicted.
//! * **Explicit invalidation** — [`DevicePool::invalidate`] unmaps every
//!   page of a uid, forcing the next acquire to rebuild and re-upload.
//!
//! Executors hold a [`PoolRef`] for the duration of a run (the buffer is
//! "on device"); dropping it returns the page to the free pool and
//! re-runs budget reclamation.

use std::collections::HashMap;
use std::ops::Deref;
use std::sync::{Arc, Mutex};

use crate::sparse::{Coo3, Csr};

use super::artifact::{PaddedCoo, PaddedEll};

/// Smallest page size class (bytes) — tiny operands round up to this.
const MIN_CLASS_BYTES: usize = 256;

/// Identity of one staged operand image: the owning handle's registration
/// uid (never reused across the process lifetime) plus a sampled content
/// fingerprint. Artifact-specific stagings of the same handle (e.g. the
/// padded COO for one PJRT bucket) salt the fingerprint so they get their
/// own page.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PoolKey {
    pub uid: u64,
    pub fp: u64,
}

impl PoolKey {
    /// Derive a variant key for an alternate staging of the same operand
    /// (same uid, fingerprint mixed with `salt`) — used to keep a PJRT
    /// bucket's padded image distinct from the raw simulator image.
    pub fn salted(self, salt: u64) -> PoolKey {
        PoolKey { uid: self.uid, fp: fnv_mix(self.fp, salt) }
    }
}

/// One FNV-1a round — the pool's (and the handles') cheap mixer.
pub fn fnv_mix(h: u64, x: u64) -> u64 {
    (h ^ x).wrapping_mul(0x100_0000_01b3)
}

/// A staged, device-resident operand image — the bytes an executor would
/// have uploaded. Building one is the "upload"; a pool hit skips it.
#[derive(Debug, Clone, PartialEq)]
pub enum DeviceImage {
    /// Raw CSR buffers (simulator staging of a matrix handle).
    Csr { indptr: Vec<u32>, indices: Vec<u32>, vals: Vec<f32> },
    /// Raw order-3 COO (simulator staging of a tensor handle).
    Tensor(Coo3),
    /// A dense operand (row-major values, possibly padded).
    Dense(Vec<f32>),
    /// Padded COO for a PJRT nnz-bucket artifact.
    Coo(PaddedCoo),
    /// Padded ELL for a PJRT row-bucket artifact.
    Ell(PaddedEll),
}

impl DeviceImage {
    /// Stage a CSR matrix (clones the three arrays — the simulated H2D
    /// copy a pool hit avoids).
    pub fn of_matrix(a: &Csr) -> DeviceImage {
        DeviceImage::Csr {
            indptr: a.indptr.clone(),
            indices: a.indices.clone(),
            vals: a.data.clone(),
        }
    }

    pub fn of_tensor(t: &Coo3) -> DeviceImage {
        DeviceImage::Tensor(t.clone())
    }

    /// Payload size in bytes (what the page's size class is derived from).
    pub fn size_bytes(&self) -> usize {
        match self {
            DeviceImage::Csr { indptr, indices, vals } => {
                4 * (indptr.len() + indices.len() + vals.len())
            }
            DeviceImage::Tensor(t) => 16 * t.nnz(),
            DeviceImage::Dense(v) => 4 * v.len(),
            DeviceImage::Coo(c) => 4 * (c.row_idx.len() + c.col_idx.len() + c.vals.len()),
            DeviceImage::Ell(e) => 4 * (e.cols.len() + e.vals.len()),
        }
    }
}

/// Point-in-time pool counters (monotonic) and gauges.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PoolStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub invalidations: u64,
    /// Bytes (size-class rounded) in pages with live [`PoolRef`]s.
    pub bytes_live: usize,
    /// Bytes (size-class rounded) in all resident pages, live or free.
    pub bytes_resident: usize,
    pub pages: usize,
}

#[derive(Debug)]
struct Page {
    class_bytes: usize,
    key: PoolKey,
    image: Arc<DeviceImage>,
    refs: usize,
    last_used: u64,
    /// Invalidated while referenced: freed (not recycled) on release.
    dead: bool,
}

#[derive(Debug)]
struct PoolInner {
    budget: usize,
    pages: HashMap<u64, Page>,
    by_key: HashMap<PoolKey, u64>,
    next_page: u64,
    tick: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
    invalidations: u64,
}

impl PoolInner {
    fn resident_bytes(&self) -> usize {
        self.pages.values().map(|p| p.class_bytes).sum()
    }

    /// Evict free pages oldest-first until residency fits the budget.
    /// Live pages are skipped — residency can exceed the budget only
    /// while over-budget images are actually in use.
    fn evict_over_budget(&mut self) {
        while self.resident_bytes() > self.budget {
            let victim = self
                .pages
                .iter()
                .filter(|(_, p)| p.refs == 0)
                .min_by_key(|(_, p)| p.last_used)
                .map(|(&id, _)| id);
            let Some(id) = victim else { break };
            let key = self.pages.remove(&id).unwrap().key;
            self.by_key.remove(&key);
            self.evictions += 1;
        }
    }

    fn release(&mut self, id: u64) {
        self.tick += 1;
        let tick = self.tick;
        let drop_page = match self.pages.get_mut(&id) {
            Some(p) => {
                p.refs -= 1;
                p.last_used = tick;
                p.refs == 0 && p.dead
            }
            None => false,
        };
        if drop_page {
            self.pages.remove(&id);
        }
        self.evict_over_budget();
    }
}

/// The shared device-buffer pool. Cheap to clone (`Arc`-backed); all
/// methods are thread-safe.
#[derive(Debug, Clone)]
pub struct DevicePool {
    inner: Arc<Mutex<PoolInner>>,
}

impl DevicePool {
    /// A pool reclaiming free pages above `budget_bytes` of residency.
    pub fn new(budget_bytes: usize) -> DevicePool {
        DevicePool {
            inner: Arc::new(Mutex::new(PoolInner {
                budget: budget_bytes,
                pages: HashMap::new(),
                by_key: HashMap::new(),
                next_page: 0,
                tick: 0,
                hits: 0,
                misses: 0,
                evictions: 0,
                invalidations: 0,
            })),
        }
    }

    /// Acquire the image for `key`: a hit pins the resident page (the
    /// upload is skipped); a miss runs `build` (the upload), preferring
    /// to recycle the least-recently-used *free* page of the same size
    /// class over growing the pool.
    pub fn acquire(&self, key: PoolKey, build: impl FnOnce() -> DeviceImage) -> PoolRef {
        self.try_acquire(key, || Ok(build())).expect("infallible build")
    }

    /// [`DevicePool::acquire`] with a fallible builder (padding can
    /// reject an operand); nothing is cached when `build` errors.
    pub fn try_acquire(
        &self,
        key: PoolKey,
        build: impl FnOnce() -> anyhow::Result<DeviceImage>,
    ) -> anyhow::Result<PoolRef> {
        let mut g = self.inner.lock().unwrap();
        g.tick += 1;
        let tick = g.tick;
        if let Some(&id) = g.by_key.get(&key) {
            g.hits += 1;
            let p = g.pages.get_mut(&id).unwrap();
            p.refs += 1;
            p.last_used = tick;
            let image = p.image.clone();
            return Ok(PoolRef { pool: self.inner.clone(), page: id, image, hit: true });
        }
        g.misses += 1;
        // The miss path builds under the lock: the executors staging here
        // are per-worker and the build is the cost being measured — a
        // concurrent same-key acquire *should* wait and then hit.
        let image = Arc::new(build()?);
        let class = class_bytes(image.size_bytes());
        let recycle = g
            .pages
            .iter()
            .filter(|(_, p)| p.refs == 0 && p.class_bytes == class)
            .min_by_key(|(_, p)| p.last_used)
            .map(|(&id, _)| id);
        let id = match recycle {
            Some(id) => {
                let old_key = {
                    let p = g.pages.get_mut(&id).unwrap();
                    let old = p.key;
                    p.key = key;
                    p.image = image.clone();
                    p.refs = 1;
                    p.last_used = tick;
                    old
                };
                g.by_key.remove(&old_key);
                id
            }
            None => {
                let id = g.next_page;
                g.next_page += 1;
                let page = Page {
                    class_bytes: class,
                    key,
                    image: image.clone(),
                    refs: 1,
                    last_used: tick,
                    dead: false,
                };
                g.pages.insert(id, page);
                id
            }
        };
        g.by_key.insert(key, id);
        g.evict_over_budget();
        Ok(PoolRef { pool: self.inner.clone(), page: id, image, hit: false })
    }

    /// Unmap every page staged for registration `uid` (all salted
    /// variants), forcing the next acquire to rebuild and re-upload.
    /// Pages still referenced stay resident until released, then free
    /// their bytes instead of returning to the pool. Returns the number
    /// of pages invalidated.
    pub fn invalidate(&self, uid: u64) -> usize {
        let mut g = self.inner.lock().unwrap();
        let ids: Vec<u64> = g
            .pages
            .iter()
            .filter(|(_, p)| p.key.uid == uid && !p.dead)
            .map(|(&id, _)| id)
            .collect();
        for &id in &ids {
            let key = {
                let p = g.pages.get_mut(&id).unwrap();
                p.dead = true;
                p.key
            };
            g.by_key.remove(&key);
            g.invalidations += 1;
        }
        let freed: Vec<u64> = ids.iter().copied().filter(|id| g.pages[id].refs == 0).collect();
        for id in freed {
            g.pages.remove(&id);
        }
        ids.len()
    }

    pub fn stats(&self) -> PoolStats {
        let g = self.inner.lock().unwrap();
        let bytes_live = g.pages.values().filter(|p| p.refs > 0).map(|p| p.class_bytes).sum();
        PoolStats {
            hits: g.hits,
            misses: g.misses,
            evictions: g.evictions,
            invalidations: g.invalidations,
            bytes_live,
            bytes_resident: g.resident_bytes(),
            pages: g.pages.len(),
        }
    }

    /// The configured byte budget.
    pub fn budget_bytes(&self) -> usize {
        self.inner.lock().unwrap().budget
    }
}

/// Size class: next power of two, floored at [`MIN_CLASS_BYTES`].
fn class_bytes(size: usize) -> usize {
    size.max(MIN_CLASS_BYTES).next_power_of_two()
}

/// A pinned staged image: derefs to the [`DeviceImage`]; dropping it
/// releases the page back to the free pool (and re-runs reclamation).
#[derive(Debug)]
pub struct PoolRef {
    pool: Arc<Mutex<PoolInner>>,
    page: u64,
    image: Arc<DeviceImage>,
    hit: bool,
}

impl PoolRef {
    /// Whether this acquire found the image resident (upload skipped).
    pub fn hit(&self) -> bool {
        self.hit
    }

    pub fn image(&self) -> &DeviceImage {
        &self.image
    }
}

impl Deref for PoolRef {
    type Target = DeviceImage;

    fn deref(&self) -> &DeviceImage {
        &self.image
    }
}

impl Drop for PoolRef {
    fn drop(&mut self) {
        if let Ok(mut g) = self.pool.lock() {
            g.release(self.page);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(uid: u64) -> PoolKey {
        PoolKey { uid, fp: uid.wrapping_mul(0x9e37_79b9_7f4a_7c15) }
    }

    fn dense(words: usize) -> DeviceImage {
        DeviceImage::Dense(vec![1.0; words])
    }

    #[test]
    fn hit_pins_and_skips_upload() {
        let pool = DevicePool::new(1 << 20);
        let mut built = 0;
        let a = pool.acquire(key(1), || {
            built += 1;
            dense(100)
        });
        assert!(!a.hit());
        drop(a);
        let b = pool.acquire(key(1), || {
            built += 1;
            dense(100)
        });
        assert!(b.hit());
        assert_eq!(built, 1, "the hit must not rebuild the image");
        let s = pool.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        assert_eq!(s.bytes_live, 512); // 400 B rounds to the 512 class
    }

    #[test]
    fn size_classes_round_up() {
        assert_eq!(class_bytes(0), MIN_CLASS_BYTES);
        assert_eq!(class_bytes(256), 256);
        assert_eq!(class_bytes(257), 512);
        assert_eq!(class_bytes(4096), 4096);
    }

    #[test]
    fn concurrent_refs_share_one_page() {
        let pool = DevicePool::new(1 << 20);
        let a = pool.acquire(key(7), || dense(10));
        let b = pool.acquire(key(7), || unreachable!("must hit"));
        assert!(b.hit());
        assert_eq!(pool.stats().pages, 1);
        drop(a);
        assert_eq!(pool.stats().bytes_live, 256, "second ref still pins the page");
        drop(b);
        assert_eq!(pool.stats().bytes_live, 0);
    }
}
