//! Artifact registry: parses `artifacts/manifest.json` (written by
//! `python/compile/aot.py`) and marshals CSR matrices into the padded
//! static-shape buffers each HLO artifact expects.
//!
//! The padding rules mirror `python/compile/kernels/common.py` exactly
//! (single source of truth is the python side; tests cross-check against
//! the oracle numerics, which would drift on any mismatch).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::sparse::Csr;

use super::json::Json;

/// Kinds of artifacts `aot.py` emits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    SpmmNnzSr,
    SpmmRowPr,
    Gcn2,
}

/// One artifact's static shapes.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub kind: ArtifactKind,
    pub file: PathBuf,
    pub rows: usize,
    pub cols: usize,
    pub n: usize,
    /// COO kinds: padded nnz; ELL kind: slots per row.
    pub nnz: usize,
    pub slots: usize,
    pub group: usize,
    pub in_feat: usize,
    pub hidden: usize,
    pub out_feat: usize,
}

impl ArtifactSpec {
    fn from_json(name: &str, dir: &Path, j: &Json) -> Result<Self> {
        let kind_s = j.get("kind").and_then(Json::as_str).context("missing kind")?;
        let kind = match kind_s {
            "spmm_nnz_sr" => ArtifactKind::SpmmNnzSr,
            "spmm_row_pr" => ArtifactKind::SpmmRowPr,
            "gcn2" => ArtifactKind::Gcn2,
            other => bail!("unknown artifact kind {other}"),
        };
        let get = |k: &str| j.get(k).and_then(Json::as_usize).unwrap_or(0);
        Ok(ArtifactSpec {
            name: name.to_string(),
            kind,
            file: dir.join(j.get("file").and_then(Json::as_str).context("missing file")?),
            rows: get("rows"),
            cols: get("cols"),
            n: get("n"),
            nnz: get("nnz"),
            slots: get("slots"),
            group: get("group"),
            in_feat: get("in_feat"),
            hidden: get("hidden"),
            out_feat: get("out_feat"),
        })
    }

    /// Can this artifact serve a `rows × cols` matrix with `nnz` non-zeros?
    pub fn admits(&self, rows: usize, cols: usize, nnz: usize) -> bool {
        rows <= self.rows
            && cols <= self.cols
            && match self.kind {
                ArtifactKind::SpmmNnzSr | ArtifactKind::Gcn2 => nnz <= self.nnz,
                ArtifactKind::SpmmRowPr => true, // per-row degree checked at pad time
            }
    }
}

/// Padded COO buffers for the nnz-SR artifacts.
#[derive(Debug, Clone, PartialEq)]
pub struct PaddedCoo {
    pub row_idx: Vec<i32>,
    pub col_idx: Vec<i32>,
    pub vals: Vec<f32>,
}

/// Pad CSR to the artifact's COO bucket. Padding entries carry
/// `row = spec.rows` (sentinel), `col = 0`, `val = 0` (zero extension).
pub fn pad_coo(a: &Csr, spec: &ArtifactSpec) -> Result<PaddedCoo> {
    if a.nnz() > spec.nnz || a.rows > spec.rows || a.cols > spec.cols {
        bail!(
            "matrix {}x{} nnz={} exceeds bucket {}x{} nnz={}",
            a.rows, a.cols, a.nnz(), spec.rows, spec.cols, spec.nnz
        );
    }
    let sentinel = spec.rows as i32;
    let mut row_idx = vec![sentinel; spec.nnz];
    let mut col_idx = vec![0i32; spec.nnz];
    let mut vals = vec![0f32; spec.nnz];
    let mut k = 0;
    for i in 0..a.rows {
        for p in a.indptr[i] as usize..a.indptr[i + 1] as usize {
            row_idx[k] = i as i32;
            col_idx[k] = a.indices[p] as i32;
            vals[k] = a.data[p];
            k += 1;
        }
    }
    Ok(PaddedCoo { row_idx, col_idx, vals })
}

/// Padded ELL buffers for the row-PR artifacts.
#[derive(Debug, Clone, PartialEq)]
pub struct PaddedEll {
    pub cols: Vec<i32>,
    pub vals: Vec<f32>,
}

pub fn pad_ell(a: &Csr, spec: &ArtifactSpec) -> Result<PaddedEll> {
    if a.rows > spec.rows || a.cols > spec.cols {
        bail!("matrix too large for ELL bucket");
    }
    if a.max_row_degree() > spec.slots {
        bail!("row degree {} exceeds bucket slots {}", a.max_row_degree(), spec.slots);
    }
    let mut cols = vec![0i32; spec.rows * spec.slots];
    let mut vals = vec![0f32; spec.rows * spec.slots];
    for i in 0..a.rows {
        let lo = a.indptr[i] as usize;
        for (s, p) in (lo..a.indptr[i + 1] as usize).enumerate() {
            cols[i * spec.slots + s] = a.indices[p] as i32;
            vals[i * spec.slots + s] = a.data[p];
        }
    }
    Ok(PaddedEll { cols, vals })
}

/// Pad a row-major dense matrix `[rows × n]` to `[spec_rows × n]`.
pub fn pad_dense(b: &[f32], rows: usize, n: usize, spec_rows: usize) -> Vec<f32> {
    assert_eq!(b.len(), rows * n);
    let mut out = vec![0f32; spec_rows * n];
    out[..rows * n].copy_from_slice(b);
    out
}

/// The artifact registry: all specs from a manifest.
#[derive(Debug, Clone, Default)]
pub struct Registry {
    pub specs: BTreeMap<String, ArtifactSpec>,
}

impl Registry {
    pub fn load(dir: &Path) -> Result<Registry> {
        let manifest = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("reading {}", manifest.display()))?;
        Self::from_json_str(&text, dir)
    }

    pub fn from_json_str(text: &str, dir: &Path) -> Result<Registry> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let obj = j.as_obj().context("manifest must be an object")?;
        let mut specs = BTreeMap::new();
        for (name, entry) in obj {
            specs.insert(name.clone(), ArtifactSpec::from_json(name, dir, entry)?);
        }
        Ok(Registry { specs })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.specs.get(name).with_context(|| format!("no artifact `{name}`"))
    }

    /// Find the best (smallest admitting) artifact of a kind for a matrix.
    pub fn route(&self, kind: ArtifactKind, rows: usize, cols: usize, nnz: usize) -> Option<&ArtifactSpec> {
        self.specs
            .values()
            .filter(|s| s.kind == kind && s.admits(rows, cols, nnz))
            .min_by_key(|s| s.rows * s.n + s.nnz)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;

    const MANIFEST: &str = r#"{
      "spmm_nnz_sr_r512_z4096_n4_g32": {"kind": "spmm_nnz_sr", "file": "a.hlo.txt",
        "rows": 512, "cols": 512, "nnz": 4096, "n": 4, "tile": 256, "group": 32},
      "spmm_row_pr_r512_s32_n4_g32": {"kind": "spmm_row_pr", "file": "b.hlo.txt",
        "rows": 512, "cols": 512, "slots": 32, "n": 4, "row_tile": 64, "group": 32},
      "gcn2": {"kind": "gcn2", "file": "g.hlo.txt", "rows": 4096, "cols": 4096,
        "nnz": 16384, "n": 16, "in_feat": 64, "hidden": 16, "out_feat": 16}
    }"#;

    fn reg() -> Registry {
        Registry::from_json_str(MANIFEST, Path::new("/art")).unwrap()
    }

    #[test]
    fn parses_manifest() {
        let r = reg();
        assert_eq!(r.specs.len(), 3);
        let s = r.get("gcn2").unwrap();
        assert_eq!(s.kind, ArtifactKind::Gcn2);
        assert_eq!(s.in_feat, 64);
        assert_eq!(s.file, PathBuf::from("/art/g.hlo.txt"));
    }

    #[test]
    fn routes_to_admitting_artifact() {
        let r = reg();
        let s = r.route(ArtifactKind::SpmmNnzSr, 100, 100, 1000).unwrap();
        assert_eq!(s.rows, 512);
        assert!(r.route(ArtifactKind::SpmmNnzSr, 1000, 100, 1000).is_none());
    }

    #[test]
    fn pad_coo_layout_matches_python() {
        let r = reg();
        let spec = r.get("spmm_nnz_sr_r512_z4096_n4_g32").unwrap();
        let a = Coo::new(3, 4, vec![(0, 1, 2.0), (2, 3, 1.5)]).to_csr();
        let p = pad_coo(&a, spec).unwrap();
        assert_eq!(p.row_idx.len(), 4096);
        assert_eq!(&p.row_idx[..3], &[0, 2, 512]); // sentinel = spec.rows
        assert_eq!(&p.col_idx[..2], &[1, 3]);
        assert_eq!(p.vals[1], 1.5);
        assert_eq!(p.vals[2], 0.0);
    }

    #[test]
    fn pad_ell_rejects_fat_rows() {
        let r = reg();
        let spec = r.get("spmm_row_pr_r512_s32_n4_g32").unwrap();
        let fat = Coo::new(64, 64, (0..40u32).map(|c| (0u32, c, 1.0f32)).collect()).to_csr();
        assert!(pad_ell(&fat, spec).is_err());
        let ok = Coo::new(4, 8, vec![(1, 2, 3.0)]).to_csr();
        let p = pad_ell(&ok, spec).unwrap();
        assert_eq!(p.cols[1 * 32], 2);
        assert_eq!(p.vals[1 * 32], 3.0);
    }

    #[test]
    fn pad_dense_extends_rows() {
        let b = vec![1.0, 2.0, 3.0, 4.0]; // 2x2
        let p = pad_dense(&b, 2, 2, 4);
        assert_eq!(p.len(), 8);
        assert_eq!(&p[..4], &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(&p[4..], &[0.0; 4]);
    }
}
