//! The PJRT-backed executor (requires the `pjrt` feature and the in-house
//! `xla` bindings).

use std::collections::HashMap;
use std::path::Path;

use anyhow::Result;

use crate::sparse::Csr;

use super::artifact::{pad_coo, pad_dense, pad_ell, ArtifactKind, PaddedCoo, Registry};

/// The PJRT-backed executor.
pub struct Runtime {
    client: xla::PjRtClient,
    pub registry: Registry,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Whether this build can execute PJRT artifacts.
    pub const fn available() -> bool {
        true
    }

    /// Load the registry and create the CPU PJRT client.
    pub fn load(artifacts_dir: &Path) -> Result<Runtime> {
        let registry = Registry::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt: {e:?}"))?;
        Ok(Runtime { client, registry, cache: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) executable for a named artifact.
    pub fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let spec = self.registry.get(name)?.clone();
            let proto = xla::HloModuleProto::from_text_file(&spec.file)
                .map_err(|e| anyhow::anyhow!("parse {}: {e:?}", spec.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(self.cache.get(name).unwrap())
    }

    pub fn is_cached(&self, name: &str) -> bool {
        self.cache.contains_key(name)
    }

    fn execute(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<f32>> {
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("sync {name}: {e:?}"))?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple
        let out = result.to_tuple1().map_err(|e| anyhow::anyhow!("tuple {name}: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec {name}: {e:?}"))
    }

    /// Run the segment-reduction SpMM artifact: `C = A · B`.
    /// Returns row-major `[a.rows × n]`.
    pub fn run_spmm_nnz(&mut self, name: &str, a: &Csr, b: &[f32]) -> Result<Vec<f32>> {
        let spec = self.registry.get(name)?.clone();
        anyhow::ensure!(spec.kind == ArtifactKind::SpmmNnzSr, "{name} is not spmm_nnz_sr");
        anyhow::ensure!(b.len() == a.cols * spec.n, "B must be cols x n");
        let coo = pad_coo(a, &spec)?;
        let bp = pad_dense(b, a.cols, spec.n, spec.cols);
        self.run_spmm_nnz_staged(name, &coo, &bp, a.rows)
    }

    /// Run the segment-reduction SpMM artifact from pre-staged padded
    /// buffers — the device-pool hot path: on a pool hit no
    /// `pad_coo`/`pad_dense` rebuild (or upload) happens at all.
    pub fn run_spmm_nnz_staged(
        &mut self,
        name: &str,
        coo: &PaddedCoo,
        bp: &[f32],
        out_rows: usize,
    ) -> Result<Vec<f32>> {
        let spec = self.registry.get(name)?.clone();
        anyhow::ensure!(spec.kind == ArtifactKind::SpmmNnzSr, "{name} is not spmm_nnz_sr");
        let n = spec.n;
        anyhow::ensure!(coo.vals.len() == spec.nnz, "staged COO must match the bucket");
        anyhow::ensure!(bp.len() == spec.cols * n, "staged B must be padded cols x n");
        let inputs = [
            xla::Literal::vec1(&coo.row_idx),
            xla::Literal::vec1(&coo.col_idx),
            xla::Literal::vec1(&coo.vals),
            xla::Literal::vec1(bp)
                .reshape(&[spec.cols as i64, n as i64])
                .map_err(|e| anyhow::anyhow!("reshape B: {e:?}"))?,
        ];
        let mut out = self.execute(name, &inputs)?;
        out.truncate(out_rows * n);
        Ok(out)
    }

    /// Run the parallel-reduction (ELL) SpMM artifact.
    pub fn run_spmm_ell(&mut self, name: &str, a: &Csr, b: &[f32]) -> Result<Vec<f32>> {
        let spec = self.registry.get(name)?.clone();
        anyhow::ensure!(spec.kind == ArtifactKind::SpmmRowPr, "{name} is not spmm_row_pr");
        let n = spec.n;
        anyhow::ensure!(b.len() == a.cols * n, "B must be cols x n");
        let ell = pad_ell(a, &spec)?;
        let bp = pad_dense(b, a.cols, n, spec.cols);
        let shape2 = |v: xla::Literal, r: usize, c: usize| {
            v.reshape(&[r as i64, c as i64]).map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
        };
        let inputs = [
            shape2(xla::Literal::vec1(&ell.cols), spec.rows, spec.slots)?,
            shape2(xla::Literal::vec1(&ell.vals), spec.rows, spec.slots)?,
            shape2(xla::Literal::vec1(&bp), spec.cols, n)?,
        ];
        let mut out = self.execute(name, &inputs)?;
        out.truncate(a.rows * n);
        Ok(out)
    }

    /// Run the 2-layer GCN forward artifact. `h` is `[a.rows × in_feat]`,
    /// `w1` `[in_feat × hidden]`, `w2` `[hidden × out_feat]`.
    pub fn run_gcn2(
        &mut self,
        name: &str,
        a: &Csr,
        h: &[f32],
        w1: &[f32],
        w2: &[f32],
    ) -> Result<Vec<f32>> {
        let spec = self.registry.get(name)?.clone();
        anyhow::ensure!(spec.kind == ArtifactKind::Gcn2, "{name} is not gcn2");
        anyhow::ensure!(a.rows == a.cols, "gcn adjacency must be square");
        let (fi, hd, fo) = (spec.in_feat, spec.hidden, spec.out_feat);
        anyhow::ensure!(h.len() == a.rows * fi, "H must be rows x in_feat");
        anyhow::ensure!(w1.len() == fi * hd && w2.len() == hd * fo, "weight shapes");
        let coo = pad_coo(a, &spec)?;
        let hp = pad_dense(h, a.rows, fi, spec.rows);
        let shape2 = |v: xla::Literal, r: usize, c: usize| {
            v.reshape(&[r as i64, c as i64]).map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
        };
        let inputs = [
            xla::Literal::vec1(&coo.row_idx),
            xla::Literal::vec1(&coo.col_idx),
            xla::Literal::vec1(&coo.vals),
            shape2(xla::Literal::vec1(&hp), spec.rows, fi)?,
            shape2(xla::Literal::vec1(w1), fi, hd)?,
            shape2(xla::Literal::vec1(w2), hd, fo)?,
        ];
        let mut out = self.execute(name, &inputs)?;
        out.truncate(a.rows * fo);
        Ok(out)
    }

    /// Artifacts directory: `$SGAP_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> std::path::PathBuf {
        super::default_artifacts_dir()
    }
}
