//! Same-API stand-in for the PJRT executor when the `pjrt` feature is off
//! (the default: the `xla` bindings are not in the offline dependency set).
//!
//! `load` always fails with an actionable message, so nothing in the
//! serving path can silently pretend to run an artifact; callers that can
//! degrade gracefully (the coordinator) check [`Runtime::available`] first
//! and use the simulator / CPU backends instead.

use std::path::Path;

use anyhow::{bail, Result};

use crate::sparse::Csr;

use super::artifact::{PaddedCoo, Registry};

const UNAVAILABLE: &str =
    "PJRT runtime unavailable: this build has the `pjrt` feature disabled \
     (the xla bindings are not in the offline dependency set); on a host \
     that has them, add the vendored `xla` dependency to rust/Cargo.toml \
     and rebuild with `--features pjrt`";

/// Stub executor: carries the (pure-rust) artifact registry but cannot run.
pub struct Runtime {
    pub registry: Registry,
}

impl Runtime {
    /// Whether this build can execute PJRT artifacts.
    pub const fn available() -> bool {
        false
    }

    /// Always fails. The registry is still parsed first so manifest errors
    /// surface with their own message.
    pub fn load(artifacts_dir: &Path) -> Result<Runtime> {
        let _registry = Registry::load(artifacts_dir)?;
        bail!(UNAVAILABLE)
    }

    pub fn platform(&self) -> String {
        "unavailable (pjrt feature off)".to_string()
    }

    pub fn is_cached(&self, _name: &str) -> bool {
        false
    }

    pub fn run_spmm_nnz(&mut self, _name: &str, _a: &Csr, _b: &[f32]) -> Result<Vec<f32>> {
        bail!(UNAVAILABLE)
    }

    pub fn run_spmm_nnz_staged(
        &mut self,
        _name: &str,
        _coo: &PaddedCoo,
        _bp: &[f32],
        _out_rows: usize,
    ) -> Result<Vec<f32>> {
        bail!(UNAVAILABLE)
    }

    pub fn run_spmm_ell(&mut self, _name: &str, _a: &Csr, _b: &[f32]) -> Result<Vec<f32>> {
        bail!(UNAVAILABLE)
    }

    pub fn run_gcn2(
        &mut self,
        _name: &str,
        _a: &Csr,
        _h: &[f32],
        _w1: &[f32],
        _w2: &[f32],
    ) -> Result<Vec<f32>> {
        bail!(UNAVAILABLE)
    }

    /// Artifacts directory: `$SGAP_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> std::path::PathBuf {
        super::default_artifacts_dir()
    }
}
