//! PJRT runtime: loads `artifacts/*.hlo.txt` (AOT-lowered by
//! `python/compile/aot.py`), compiles them on the PJRT CPU client, caches
//! the executables, and runs them from the rust hot path — Python never
//! executes at serve time.
//!
//! Pattern follows `/opt/xla-example/load_hlo`: HLO **text** is the
//! interchange format (jax ≥ 0.5 emits 64-bit-id protos that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids).
//!
//! The PJRT client comes from the in-house `xla` bindings, which are not
//! part of the offline dependency set, so the executor is gated behind the
//! `pjrt` cargo feature. Without it [`Runtime`] is a same-API stub whose
//! `load` fails with a clear message; the registry/padding layer
//! ([`artifact`]) is pure rust and always available, and the coordinator
//! checks [`Runtime::available`] before attempting artifact routing.

pub mod artifact;
pub mod json;
pub mod pool;

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::Runtime;

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::Runtime;

pub use artifact::{ArtifactKind, ArtifactSpec, PaddedCoo, PaddedEll, Registry};
pub use pool::{DeviceImage, DevicePool, PoolKey, PoolRef, PoolStats};

/// Artifacts directory: `$SGAP_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("SGAP_ARTIFACTS")
        .map(Into::into)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
