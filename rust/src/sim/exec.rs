//! Warp-level interpreter: 32 lanes, lane masks, divergence, group
//! reduction macro-instructions. Produces numerics + a [`WarpCost`].
//!
//! Executes the slot-resolved form ([`crate::sim::resolve`]) — the hot
//! loop does no string hashing and no per-warp allocation beyond the
//! slot vector (§Perf pass; see EXPERIMENTS.md).

use thiserror::Error;

use super::cost::{distinct_sectors, CostParams, WarpCost};
use super::memory::{DeviceMemory, MemError};
use super::resolve::{ResolvedKernel, RStmt, RVal};
use crate::compiler::llir::BinOp;

pub const WARP: usize = 32;

#[derive(Debug, Error)]
pub enum ExecError {
    #[error("memory: {0}")]
    Mem(#[from] MemError),
    #[error("non-uniform group writeback index in atomicAddGroup (lane {lane}: {got} != {want})")]
    NonUniformGroupIndex { lane: usize, got: i64, want: i64 },
    #[error("infinite loop guard tripped ({0} iterations)")]
    LoopGuard(u64),
}

/// A per-lane value: integer or float.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum V {
    I(i64),
    F(f32),
}

impl V {
    #[inline]
    fn as_f(self) -> f32 {
        match self {
            V::I(i) => i as f32,
            V::F(f) => f,
        }
    }
    #[inline]
    fn as_i(self) -> i64 {
        match self {
            V::I(i) => i,
            V::F(f) => f as i64,
        }
    }
    #[inline]
    fn truthy(self) -> bool {
        match self {
            V::I(i) => i != 0,
            V::F(f) => f != 0.0,
        }
    }
}

type Lanes = [V; WARP];

const ZERO: Lanes = [V::I(0); WARP];

/// FNV-1a-ish mix for the per-warp sector cache key.
#[inline]
fn sector_key(array: u16, sector: u64) -> u64 {
    (array as u64 + 1).wrapping_mul(0x100000001b3) ^ sector.wrapping_mul(0x9E3779B97F4A7C15)
}

/// Identity hasher for already-mixed u64 keys (the default SipHash showed
/// up as the top cost of the sector cache in the §Perf pass).
#[derive(Default)]
pub struct IdentityHasher(u64);

impl std::hash::Hasher for IdentityHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.0
    }
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 = (self.0 << 8) | b as u64;
        }
    }
    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.0 = v;
    }
}

type SectorSet = std::collections::HashSet<u64, std::hash::BuildHasherDefault<IdentityHasher>>;

/// Executes one warp of a resolved kernel.
pub struct WarpExecutor<'a> {
    mem: &'a mut DeviceMemory,
    params: &'a CostParams,
    pub cost: WarpCost,
    env: Vec<Lanes>,
    block_idx: i64,
    /// threadIdx.x of lane 0.
    warp_base: i64,
    /// Active-lane mask for lanes beyond blockDim.
    shape_mask: u32,
    /// Safety guard for while loops.
    max_iters: u64,
    /// L1-model: sectors already fetched by this warp cost no DRAM
    /// traffic again.
    seen_sectors: SectorSet,
    /// Scratch for atomic serialization accounting.
    addr_scratch: Vec<i64>,
}

#[inline]
fn lanes_of(mask: u32) -> impl Iterator<Item = usize> {
    let mut m = mask;
    std::iter::from_fn(move || {
        if m == 0 {
            None
        } else {
            let l = m.trailing_zeros() as usize;
            m &= m - 1;
            Some(l)
        }
    })
}

/// Max multiplicity of any address (the atomic serialization depth).
fn max_multiplicity(addrs: &mut Vec<i64>) -> u64 {
    if addrs.is_empty() {
        return 0;
    }
    addrs.sort_unstable();
    let mut best = 1u64;
    let mut run = 1u64;
    for i in 1..addrs.len() {
        if addrs[i] == addrs[i - 1] {
            run += 1;
            best = best.max(run);
        } else {
            run = 1;
        }
    }
    best
}

impl<'a> WarpExecutor<'a> {
    pub fn new(
        mem: &'a mut DeviceMemory,
        params: &'a CostParams,
        block_idx: u32,
        warp_in_block: u32,
        block_dim: u32,
    ) -> Self {
        let warp_base = (warp_in_block as i64) * WARP as i64;
        let mut shape_mask = 0u32;
        for l in 0..WARP {
            if warp_base + (l as i64) < block_dim as i64 {
                shape_mask |= 1 << l;
            }
        }
        WarpExecutor {
            mem,
            params,
            cost: WarpCost::default(),
            env: Vec::new(),
            block_idx: block_idx as i64,
            warp_base,
            shape_mask,
            max_iters: 100_000_000,
            seen_sectors: SectorSet::default(),
            addr_scratch: Vec::with_capacity(WARP),
        }
    }

    /// Run the kernel body for this warp.
    pub fn run(&mut self, kernel: &ResolvedKernel) -> Result<(), ExecError> {
        let mask = self.shape_mask;
        if mask == 0 {
            return Ok(());
        }
        self.env.clear();
        self.env.resize(kernel.slots as usize, ZERO);
        let mut broke = 0u32;
        self.exec_block(&kernel.body, mask, &mut broke)
    }

    /// Count DRAM sectors for `addrs`, filtered through the per-warp cache
    /// (re-touched sectors are L1 hits: no DRAM traffic).
    fn fresh_sectors(&mut self, array: u16, iv: &Lanes, mask: u32) -> u64 {
        let mut fresh = 0u64;
        for l in lanes_of(mask) {
            let sector = (iv[l].as_i().max(0) as u64 * 4) / 32;
            if self.seen_sectors.insert(sector_key(array, sector)) {
                fresh += 1;
            }
        }
        fresh
    }

    // ---- expression evaluation -------------------------------------------

    fn eval(&mut self, v: &RVal, mask: u32) -> Result<Lanes, ExecError> {
        match v {
            RVal::Var(slot) => Ok(self.env[*slot as usize]),
            RVal::ConstI(c) => Ok([V::I(*c); WARP]),
            RVal::ConstF(c) => Ok([V::F(*c); WARP]),
            RVal::BlockIdx => Ok([V::I(self.block_idx); WARP]),
            RVal::ThreadIdx => {
                let mut out = ZERO;
                for (l, o) in out.iter_mut().enumerate() {
                    *o = V::I(self.warp_base + l as i64);
                }
                Ok(out)
            }
            RVal::Bin(op, a, b) => {
                let av = self.eval(a, mask)?;
                let bv = self.eval(b, mask)?;
                self.cost.add_alu(self.params, 1.0);
                let mut out = ZERO;
                for l in lanes_of(mask) {
                    out[l] = bin_op(*op, av[l], bv[l]);
                }
                Ok(out)
            }
            RVal::Load { array, int, idx } => {
                let iv = self.eval(idx, mask)?;
                let id = *array as usize;
                let mut out = ZERO;
                if *int {
                    for l in lanes_of(mask) {
                        out[l] = V::I(self.mem.load_i_id(id, iv[l].as_i())?);
                    }
                } else {
                    for l in lanes_of(mask) {
                        out[l] = V::F(self.mem.load_num_id(id, iv[l].as_i())? as f32);
                    }
                }
                let sectors = self.fresh_sectors(*array, &iv, mask);
                self.cost.add_load(self.params, sectors);
                Ok(out)
            }
            RVal::BinarySearchBefore { array, lo, hi, target } => {
                let lov = self.eval(lo, mask)?;
                let hiv = self.eval(hi, mask)?;
                let tv = self.eval(target, mask)?;
                let id = *array as usize;
                let mut out = ZERO;
                let mut max_steps = 0u32;
                for l in lanes_of(mask) {
                    let (mut lo, mut hi) = (lov[l].as_i(), hiv[l].as_i());
                    let t = tv[l].as_i();
                    let mut steps = 0u32;
                    // largest i in [lo, hi] with array[i] <= t
                    while lo < hi {
                        let mid = (lo + hi + 1) / 2;
                        if self.mem.load_i_id(id, mid)? <= t {
                            lo = mid;
                        } else {
                            hi = mid - 1;
                        }
                        steps += 1;
                    }
                    max_steps = max_steps.max(steps);
                    out[l] = V::I(lo);
                }
                // warp executes in lockstep: cost = slowest lane's steps,
                // each step is a compare + dependent (uncoalesced) load
                self.cost.add_alu(self.params, self.params.bsearch_step * max_steps as f64);
                self.cost.sectors += max_steps as u64; // dependent scattered loads
                Ok(out)
            }
        }
    }

    // ---- statement execution ---------------------------------------------

    #[inline]
    fn write_lanes(&mut self, slot: u16, vals: &Lanes, mask: u32, float: bool) {
        let entry = &mut self.env[slot as usize];
        if float {
            for l in lanes_of(mask) {
                entry[l] = V::F(vals[l].as_f());
            }
        } else {
            for l in lanes_of(mask) {
                entry[l] = V::I(vals[l].as_i());
            }
        }
    }

    fn exec_block(&mut self, stmts: &[RStmt], mask: u32, broke: &mut u32) -> Result<(), ExecError> {
        for s in stmts {
            let active = mask & !*broke;
            if active == 0 {
                break;
            }
            self.exec_stmt(s, active, broke)?;
        }
        Ok(())
    }

    fn exec_stmt(&mut self, s: &RStmt, mask: u32, broke: &mut u32) -> Result<(), ExecError> {
        match s {
            RStmt::Decl { var, init, float } => {
                let vals = self.eval(init, mask)?;
                self.write_lanes(*var, &vals, mask, *float);
                Ok(())
            }
            RStmt::Assign { var, val, float } => {
                let vals = self.eval(val, mask)?;
                self.write_lanes(*var, &vals, mask, *float);
                Ok(())
            }
            RStmt::Store { array, idx, val } => {
                let iv = self.eval(idx, mask)?;
                let vv = self.eval(val, mask)?;
                let id = *array as usize;
                for l in lanes_of(mask) {
                    self.mem.store_f_id(id, iv[l].as_i(), vv[l].as_f())?;
                }
                // stores are write-through: always DRAM traffic
                let sectors =
                    distinct_sectors(lanes_of(mask).map(|l| iv[l].as_i().max(0) as usize), 4);
                self.cost.add_load(self.params, sectors);
                Ok(())
            }
            RStmt::AtomicAdd { array, idx, val } => {
                let iv = self.eval(idx, mask)?;
                let vv = self.eval(val, mask)?;
                let id = *array as usize;
                // predicated on value != 0 (skip useless atomics)
                self.addr_scratch.clear();
                for l in lanes_of(mask) {
                    let v = vv[l].as_f();
                    if v != 0.0 {
                        self.mem.atomic_add_f_id(id, iv[l].as_i(), v)?;
                        self.addr_scratch.push(iv[l].as_i());
                    }
                }
                if !self.addr_scratch.is_empty() {
                    let mut scratch = std::mem::take(&mut self.addr_scratch);
                    let serialized = max_multiplicity(&mut scratch);
                    self.addr_scratch = scratch;
                    self.cost.add_atomics(self.params, serialized);
                }
                Ok(())
            }
            RStmt::AtomicAddGroup { array, idx, val, group } => {
                self.group_atomic_add(*array, idx, val, *group, mask)
            }
            RStmt::SegReduceGroup { array, idx, val, group } => {
                self.group_seg_reduce(*array, idx, val, *group, mask)
            }
            RStmt::If { cond, then, els } => {
                let cv = self.eval(cond, mask)?;
                let mut m_then = 0u32;
                for l in lanes_of(mask) {
                    if cv[l].truthy() {
                        m_then |= 1 << l;
                    }
                }
                let m_else = mask & !m_then;
                if m_then != 0 {
                    self.cost.add_alu(self.params, self.params.branch);
                    self.exec_block(then, m_then, broke)?;
                }
                if m_else != 0 && !els.is_empty() {
                    self.cost.add_alu(self.params, self.params.branch);
                    self.exec_block(els, m_else, broke)?;
                }
                Ok(())
            }
            RStmt::While { cond, body } => {
                let mut active = mask;
                let mut iters = 0u64;
                loop {
                    let cv = self.eval(cond, active)?;
                    let mut next = 0u32;
                    for l in lanes_of(active) {
                        if cv[l].truthy() {
                            next |= 1 << l;
                        }
                    }
                    if next == 0 {
                        break;
                    }
                    let mut loop_broke = 0u32;
                    self.exec_block(body, next, &mut loop_broke)?;
                    active = next & !loop_broke;
                    self.cost.add_alu(self.params, self.params.branch);
                    iters += 1;
                    if iters > self.max_iters {
                        return Err(ExecError::LoopGuard(iters));
                    }
                }
                Ok(())
            }
            RStmt::For { var, lo, hi, step, body } => {
                let lov = self.eval(lo, mask)?;
                self.write_lanes(*var, &lov, mask, false);
                let mut active = mask;
                let mut iters = 0u64;
                loop {
                    let hiv = self.eval(hi, active)?;
                    let cur = self.env[*var as usize];
                    let mut next = 0u32;
                    for l in lanes_of(active) {
                        if cur[l].as_i() < hiv[l].as_i() {
                            next |= 1 << l;
                        }
                    }
                    if next == 0 {
                        break;
                    }
                    let mut loop_broke = 0u32;
                    self.exec_block(body, next, &mut loop_broke)?;
                    active = next & !loop_broke;
                    // increment surviving lanes
                    let stepv = self.eval(step, active)?;
                    let entry = &mut self.env[*var as usize];
                    for l in lanes_of(active) {
                        entry[l] = V::I(entry[l].as_i() + stepv[l].as_i());
                    }
                    self.cost.add_alu(self.params, self.params.branch);
                    iters += 1;
                    if iters > self.max_iters {
                        return Err(ExecError::LoopGuard(iters));
                    }
                }
                Ok(())
            }
            RStmt::Break => {
                *broke |= mask;
                Ok(())
            }
        }
    }

    // ---- macro instructions (§5.3) ----------------------------------------

    /// `atomicAddGroup<float, G>`: tree-reduce over each aligned G-lane
    /// subgroup, lane 0 writes back. Writeback is skipped for subgroups
    /// with zero contribution (predicated atomic).
    fn group_atomic_add(
        &mut self,
        array: u16,
        idx: &RVal,
        val: &RVal,
        group: u32,
        mask: u32,
    ) -> Result<(), ExecError> {
        let iv = self.eval(idx, mask)?;
        let vv = self.eval(val, mask)?;
        if mask == 0 {
            return Ok(());
        }
        self.cost.add_group_reduce(self.params, group, 1.0);
        let g = group as usize;
        let id = array as usize;
        self.addr_scratch.clear();
        for sg in 0..(WARP / g) {
            let sub = ((1u64 << g) - 1) as u32;
            let sub_mask = mask & (sub << (sg * g));
            if sub_mask == 0 {
                continue;
            }
            let first = sub_mask.trailing_zeros() as usize;
            let addr = iv[first].as_i();
            if cfg!(debug_assertions) {
                for l in lanes_of(sub_mask) {
                    if iv[l].as_i() != addr {
                        return Err(ExecError::NonUniformGroupIndex {
                            lane: l,
                            got: iv[l].as_i(),
                            want: addr,
                        });
                    }
                }
            }
            let mut sum = 0.0f32;
            for l in lanes_of(sub_mask) {
                sum += vv[l].as_f();
            }
            if sum != 0.0 {
                self.mem.atomic_add_f_id(id, addr, sum)?;
                self.addr_scratch.push(addr);
            }
        }
        if !self.addr_scratch.is_empty() {
            let mut scratch = std::mem::take(&mut self.addr_scratch);
            let serialized = max_multiplicity(&mut scratch);
            self.addr_scratch = scratch;
            self.cost.add_atomics(self.params, serialized);
        }
        Ok(())
    }

    /// `segReduceGroup<float, G>`: segmented scan over each aligned G-lane
    /// subgroup keyed by `idx`; segment-end lanes write back.
    fn group_seg_reduce(
        &mut self,
        array: u16,
        idx: &RVal,
        val: &RVal,
        group: u32,
        mask: u32,
    ) -> Result<(), ExecError> {
        let iv = self.eval(idx, mask)?;
        let vv = self.eval(val, mask)?;
        if mask == 0 {
            return Ok(());
        }
        // scan shuffles carry value + key: 2 shfl per step
        self.cost.add_group_reduce(self.params, group, 2.0);
        let g = group as usize;
        let id = array as usize;
        self.addr_scratch.clear();
        for sg in 0..(WARP / g) {
            let sub = ((1u64 << g) - 1) as u32;
            let sub_mask = mask & (sub << (sg * g));
            if sub_mask == 0 {
                continue;
            }
            let mut run_idx = i64::MIN;
            let mut acc = 0.0f32;
            for l in lanes_of(sub_mask) {
                let li = iv[l].as_i();
                if li != run_idx {
                    if acc != 0.0 {
                        self.mem.atomic_add_f_id(id, run_idx, acc)?;
                        self.addr_scratch.push(run_idx);
                    }
                    run_idx = li;
                    acc = 0.0;
                }
                acc += vv[l].as_f();
            }
            if acc != 0.0 {
                self.mem.atomic_add_f_id(id, run_idx, acc)?;
                self.addr_scratch.push(run_idx);
            }
        }
        if !self.addr_scratch.is_empty() {
            let mut scratch = std::mem::take(&mut self.addr_scratch);
            let serialized = max_multiplicity(&mut scratch);
            self.addr_scratch = scratch;
            self.cost.add_atomics(self.params, serialized);
        }
        Ok(())
    }
}

fn bin_op(op: BinOp, a: V, b: V) -> V {
    use BinOp::*;
    let both_int = matches!((a, b), (V::I(_), V::I(_)));
    match op {
        Add | Sub | Mul | Div | Mod | Min => {
            if both_int {
                let (x, y) = (a.as_i(), b.as_i());
                V::I(match op {
                    Add => x + y,
                    Sub => x - y,
                    Mul => x * y,
                    Div => {
                        if y == 0 {
                            0
                        } else {
                            x / y
                        }
                    }
                    Mod => {
                        if y == 0 {
                            0
                        } else {
                            x % y
                        }
                    }
                    Min => x.min(y),
                    _ => unreachable!(),
                })
            } else {
                let (x, y) = (a.as_f(), b.as_f());
                V::F(match op {
                    Add => x + y,
                    Sub => x - y,
                    Mul => x * y,
                    Div => x / y,
                    Mod => x % y,
                    Min => x.min(y),
                    _ => unreachable!(),
                })
            }
        }
        Lt | Le | Eq | Ne | Ge | Gt => {
            let r = if both_int {
                let (x, y) = (a.as_i(), b.as_i());
                match op {
                    Lt => x < y,
                    Le => x <= y,
                    Eq => x == y,
                    Ne => x != y,
                    Ge => x >= y,
                    Gt => x > y,
                    _ => unreachable!(),
                }
            } else {
                let (x, y) = (a.as_f(), b.as_f());
                match op {
                    Lt => x < y,
                    Le => x <= y,
                    Eq => x == y,
                    Ne => x != y,
                    Ge => x >= y,
                    Gt => x > y,
                    _ => unreachable!(),
                }
            };
            V::I(r as i64)
        }
        And => V::I((a.truthy() && b.truthy()) as i64),
        Or => V::I((a.truthy() || b.truthy()) as i64),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::llir::{Kernel, Param, Stmt, Val as LVal};
    use crate::sim::resolve::resolve;

    fn tiny_kernel(body: Vec<Stmt>) -> Kernel {
        Kernel { name: "t".into(), params: vec![Param::f32_array("out")], body, block_dim: 32 }
    }

    fn run_one_warp(k: &Kernel, mem: &mut DeviceMemory) -> WarpCost {
        let p = CostParams::default();
        let rk = resolve(k, mem).unwrap();
        let mut ex = WarpExecutor::new(mem, &p, 0, 0, rk.block_dim);
        ex.run(&rk).unwrap();
        ex.cost
    }

    #[test]
    fn store_per_lane() {
        let k = tiny_kernel(vec![Stmt::Store {
            array: "out".into(),
            idx: LVal::ThreadIdx,
            val: LVal::bin(BinOp::Mul, LVal::ThreadIdx, LVal::ConstI(2)),
        }]);
        let mut mem = DeviceMemory::new();
        mem.bind_f32("out", vec![0.0; 32]);
        run_one_warp(&k, &mut mem);
        let out = mem.f32_slice("out").unwrap();
        assert_eq!(out[5], 10.0);
        assert_eq!(out[31], 62.0);
    }

    #[test]
    fn divergent_if() {
        // lanes < 16 write 1, others write 2
        let k = tiny_kernel(vec![Stmt::If {
            cond: LVal::lt(LVal::ThreadIdx, LVal::ConstI(16)),
            then: vec![Stmt::Store { array: "out".into(), idx: LVal::ThreadIdx, val: LVal::ConstF(1.0) }],
            els: vec![Stmt::Store { array: "out".into(), idx: LVal::ThreadIdx, val: LVal::ConstF(2.0) }],
        }]);
        let mut mem = DeviceMemory::new();
        mem.bind_f32("out", vec![0.0; 32]);
        run_one_warp(&k, &mut mem);
        let out = mem.f32_slice("out").unwrap();
        assert_eq!(out[0], 1.0);
        assert_eq!(out[16], 2.0);
    }

    #[test]
    fn while_with_divergent_trip_counts() {
        // lane l sums l values => out[l] = l
        let k = tiny_kernel(vec![
            Stmt::Decl { var: "acc".into(), init: LVal::ConstF(0.0), float: true },
            Stmt::Decl { var: "i".into(), init: LVal::ConstI(0), float: false },
            Stmt::While {
                cond: LVal::lt(LVal::var("i"), LVal::ThreadIdx),
                body: vec![
                    Stmt::Assign { var: "acc".into(), val: LVal::add(LVal::var("acc"), LVal::ConstF(1.0)) },
                    Stmt::Assign { var: "i".into(), val: LVal::add(LVal::var("i"), LVal::ConstI(1)) },
                ],
            },
            Stmt::Store { array: "out".into(), idx: LVal::ThreadIdx, val: LVal::var("acc") },
        ]);
        let mut mem = DeviceMemory::new();
        mem.bind_f32("out", vec![0.0; 32]);
        run_one_warp(&k, &mut mem);
        let out = mem.f32_slice("out").unwrap();
        for l in 0..32 {
            assert_eq!(out[l], l as f32, "lane {l}");
        }
    }

    #[test]
    fn for_with_break() {
        // break when i == 3 => out[l] = 3 for all lanes
        let k = tiny_kernel(vec![
            Stmt::Decl { var: "acc".into(), init: LVal::ConstF(0.0), float: true },
            Stmt::For {
                var: "i".into(),
                lo: LVal::ConstI(0),
                hi: LVal::ConstI(10),
                step: LVal::ConstI(1),
                body: vec![
                    Stmt::If {
                        cond: LVal::eq(LVal::var("i"), LVal::ConstI(3)),
                        then: vec![Stmt::Break],
                        els: vec![],
                    },
                    Stmt::Assign { var: "acc".into(), val: LVal::add(LVal::var("acc"), LVal::ConstF(1.0)) },
                ],
            },
            Stmt::Store { array: "out".into(), idx: LVal::ThreadIdx, val: LVal::var("acc") },
        ]);
        let mut mem = DeviceMemory::new();
        mem.bind_f32("out", vec![0.0; 32]);
        run_one_warp(&k, &mut mem);
        assert_eq!(mem.f32_slice("out").unwrap()[7], 3.0);
    }

    #[test]
    fn atomic_add_group_sums_subgroups() {
        // group 8: subgroup s writes sum of its lane ids to out[s]
        let k = tiny_kernel(vec![
            Stmt::Decl { var: "sg".into(), init: LVal::div(LVal::ThreadIdx, LVal::ConstI(8)), float: false },
            Stmt::AtomicAddGroup {
                array: "out".into(),
                idx: LVal::var("sg"),
                val: LVal::bin(BinOp::Add, LVal::ConstF(0.0), LVal::ThreadIdx),
                group: 8,
            },
        ]);
        let mut mem = DeviceMemory::new();
        mem.bind_f32("out", vec![0.0; 4]);
        run_one_warp(&k, &mut mem);
        let out = mem.f32_slice("out").unwrap();
        assert_eq!(out, &[28.0, 92.0, 156.0, 220.0]); // sums of 0..8, 8..16, ...
    }

    #[test]
    fn seg_reduce_group_respects_segments() {
        // idx = lane / 4 (8 segments of 4 lanes), val = 1 => out[s] = 4
        let k = tiny_kernel(vec![
            Stmt::Decl { var: "s".into(), init: LVal::div(LVal::ThreadIdx, LVal::ConstI(4)), float: false },
            Stmt::SegReduceGroup {
                array: "out".into(),
                idx: LVal::var("s"),
                val: LVal::ConstF(1.0),
                group: 32,
            },
        ]);
        let mut mem = DeviceMemory::new();
        mem.bind_f32("out", vec![0.0; 8]);
        run_one_warp(&k, &mut mem);
        assert_eq!(mem.f32_slice("out").unwrap(), &[4.0; 8]);
    }

    #[test]
    fn seg_reduce_segment_straddling_group_boundary_uses_two_writebacks() {
        // one segment across all 32 lanes, group 8 => 4 partial writebacks
        let k = tiny_kernel(vec![Stmt::SegReduceGroup {
            array: "out".into(),
            idx: LVal::ConstI(0),
            val: LVal::ConstF(1.0),
            group: 8,
        }]);
        let mut mem = DeviceMemory::new();
        mem.bind_f32("out", vec![0.0; 1]);
        let cost = run_one_warp(&k, &mut mem);
        assert_eq!(mem.f32_slice("out").unwrap()[0], 32.0);
        assert_eq!(cost.atomic_updates, 4); // serialized: same address
    }

    #[test]
    fn group_cost_smaller_for_smaller_r() {
        let mk = |r: u32| {
            tiny_kernel(vec![Stmt::AtomicAddGroup {
                array: "out".into(),
                idx: LVal::div(LVal::ThreadIdx, LVal::ConstI(r as i64)),
                val: LVal::ConstF(1.0),
                group: r,
            }])
        };
        let p = CostParams::default();
        let mut cost = vec![];
        for r in [8u32, 32] {
            let k = mk(r);
            let mut mem = DeviceMemory::new();
            mem.bind_f32("out", vec![0.0; 8]);
            let rk = resolve(&k, &mem).unwrap();
            let mut ex = WarpExecutor::new(&mut mem, &p, 0, 0, 32);
            ex.run(&rk).unwrap();
            cost.push(ex.cost.compute_cycles);
        }
        assert!(cost[0] < cost[1], "r=8 ({}) should beat r=32 ({})", cost[0], cost[1]);
    }

    #[test]
    fn binary_search_before_semantics() {
        let k = Kernel {
            name: "t".into(),
            params: vec![Param::f32_array("out"), Param::i32_array("pos")],
            block_dim: 32,
            body: vec![
                Stmt::Decl {
                    var: "i".into(),
                    init: LVal::BinarySearchBefore {
                        array: "pos".into(),
                        lo: Box::new(LVal::ConstI(0)),
                        hi: Box::new(LVal::ConstI(4)),
                        target: Box::new(LVal::ThreadIdx),
                    },
                    float: false,
                },
                Stmt::Store {
                    array: "out".into(),
                    idx: LVal::ThreadIdx,
                    val: LVal::bin(BinOp::Add, LVal::ConstF(0.0), LVal::var("i")),
                },
            ],
        };
        let mut mem = DeviceMemory::new();
        // pos = [0,2,3,3,6]: row of nnz t
        mem.bind_i32("pos", vec![0, 2, 3, 3, 6]);
        mem.bind_f32("out", vec![0.0; 32]);
        run_one_warp(&k, &mut mem);
        let out = mem.f32_slice("out").unwrap();
        assert_eq!(out[0], 0.0);
        assert_eq!(out[1], 0.0);
        assert_eq!(out[2], 1.0);
        assert_eq!(out[3], 3.0); // pos[3]=3<=3
        assert_eq!(out[6], 4.0);
    }

    #[test]
    fn partial_warp_masks_tail_lanes() {
        let mut k = tiny_kernel(vec![Stmt::Store {
            array: "out".into(),
            idx: LVal::ThreadIdx,
            val: LVal::ConstF(1.0),
        }]);
        k.block_dim = 20; // only 20 threads
        let mut mem = DeviceMemory::new();
        mem.bind_f32("out", vec![0.0; 32]);
        run_one_warp(&k, &mut mem);
        let out = mem.f32_slice("out").unwrap();
        assert_eq!(out[19], 1.0);
        assert_eq!(out[20], 0.0);
    }
}
