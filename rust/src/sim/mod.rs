//! The SIMT cost simulator — the stand-in for the paper's three GPUs
//! (DESIGN.md §2).
//!
//! [`exec`] interprets compiler-emitted LLIR kernels warp-by-warp with
//! 32-lane masks, producing *both* the numeric result and a cycle/sector
//! cost account. [`machine`] rolls warp costs up to a kernel time under a
//! roofline-style SM/DRAM model parameterized by [`HwProfile`]s matching
//! the paper's RTX 3090 / RTX 2080 / Tesla V100 (§7, experiment settings).
//!
//! ## Cost model (also DESIGN.md §cost-model)
//!
//! * ALU op: 1 cycle/warp-instruction; divergent `if` pays both sides.
//! * Global load: fixed issue cost + one 32-byte **sector** per distinct
//!   sector touched by active lanes (coalescing model).
//! * `atomicAdd`: issue + serialization by address multiplicity.
//! * Group reduce (`atomicAddGroup`/`segReduceGroup` with width `r`):
//!   `log2(r)` shuffle steps; each step carries a **convergence overhead
//!   proportional to the synchronized width** (`sync_per_lane · r`). This
//!   is the simulator's rendering of Fig. 1(b): lanes that do not carry
//!   data still have to arrive at the synchronization point, and wider
//!   groups wait longer. It is what makes flexible group size (Table 1)
//!   pay off; the constant is calibrated so the r=8-vs-32 gain on
//!   short-row matrices lands in the paper's 2× band.
//! * Zero-contribution subgroups skip their writeback (the emitted macro
//!   predicates the atomic on `value != 0`).
//!
//! Kernel time = `max(compute bound, DRAM bound, critical warp)` over
//! SMs + launch overhead. Absolute times are *estimates*; the experiments
//! only consume ratios (who wins, by how much), per DESIGN.md.

pub mod cost;
pub mod exec;
pub mod machine;
pub mod memory;
pub mod resolve;

pub use cost::{CostParams, WarpCost};
pub use exec::{ExecError, WarpExecutor};
pub use machine::{HwProfile, KernelReport, Machine};
pub use memory::{Buffer, DeviceMemory};
pub use resolve::{resolve, ResolvedKernel};
