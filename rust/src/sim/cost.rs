//! Cost parameters and per-warp cost accounts.
//!
//! One [`CostParams`] instance is shared by the LLIR interpreter and the
//! hand-written dgSPARSE kernels (`algos::dgsparse`), so compiler-generated
//! and library kernels are priced identically.

/// Microarchitectural cost constants (cycles unless noted).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostParams {
    /// One warp-wide ALU instruction.
    pub alu: f64,
    /// Issue cost of a global load/store instruction (pipeline slot, not
    /// DRAM time — DRAM is accounted via sectors).
    pub load_issue: f64,
    /// One `__shfl_*_sync` step.
    pub shfl: f64,
    /// Convergence overhead per synchronized lane per reduce step —
    /// the Fig. 1(b) "waiting" cost; see module docs of [`crate::sim`].
    pub sync_per_lane: f64,
    /// Serialized atomic update to one address.
    pub atomic: f64,
    /// Branch/divergence bookkeeping per taken side.
    pub branch: f64,
    /// Binary-search step (compare + dependent load issue).
    pub bsearch_step: f64,
}

impl Default for CostParams {
    fn default() -> Self {
        CostParams {
            alu: 1.0,
            load_issue: 4.0,
            shfl: 2.0,
            // calibrated so the Table-1 r=8-vs-32 gain on the synthetic
            // suite lands in the paper's band (see DESIGN.md §cost-model)
            sync_per_lane: 1.0,
            atomic: 4.0,
            branch: 1.0,
            bsearch_step: 6.0,
        }
    }
}

impl CostParams {
    /// Number of tunable parameters (the calibration vector length).
    pub const N: usize = 7;

    /// Stable parameter names, in [`CostParams::to_array`] order — the
    /// key order of the calibration artifact
    /// (`tuner::calibrate::Calibration`).
    pub const NAMES: [&'static str; CostParams::N] =
        ["alu", "load_issue", "shfl", "sync_per_lane", "atomic", "branch", "bsearch_step"];

    /// The parameter vector, in [`CostParams::NAMES`] order. Together
    /// with [`CostParams::from_array`] this makes the params settable by
    /// the calibration fitter instead of `Default`-only.
    pub fn to_array(&self) -> [f64; CostParams::N] {
        [
            self.alu,
            self.load_issue,
            self.shfl,
            self.sync_per_lane,
            self.atomic,
            self.branch,
            self.bsearch_step,
        ]
    }

    /// Rebuild params from a fitted vector (inverse of
    /// [`CostParams::to_array`]).
    pub fn from_array(v: [f64; CostParams::N]) -> CostParams {
        CostParams {
            alu: v[0],
            load_issue: v[1],
            shfl: v[2],
            sync_per_lane: v[3],
            atomic: v[4],
            branch: v[5],
            bsearch_step: v[6],
        }
    }

    /// Cost of one tree/scan reduction over a group of width `r`:
    /// `log2(r)` steps of `shfl_per_step` shuffles plus width-proportional
    /// convergence overhead.
    pub fn group_reduce(&self, r: u32, shfl_per_step: f64) -> f64 {
        let steps = (r.max(1) as f64).log2();
        steps * (shfl_per_step * self.shfl + self.sync_per_lane * r as f64)
    }

    /// `atomicAddGroup<float, r>`: tree reduction, 1 shuffle per step.
    /// The closed form the analytic model (`tuner::model`) prices with —
    /// identical to what [`WarpCost::add_group_reduce`] charges in
    /// `sim::exec::WarpExecutor::group_atomic_add`.
    pub fn par_reduce(&self, r: u32) -> f64 {
        self.group_reduce(r, 1.0)
    }

    /// `segReduceGroup<float, r>`: segmented scan, the shuffles carry
    /// value + key — 2 shuffles per step (mirrors
    /// `sim::exec::WarpExecutor::group_seg_reduce`).
    pub fn seg_scan(&self, r: u32) -> f64 {
        self.group_reduce(r, 2.0)
    }

    /// Serialized-atomic cycles for a writeback whose worst address is hit
    /// `multiplicity` times (the interpreter charges
    /// `atomic × max_multiplicity`; the model passes an expectation).
    pub fn atomic_chain(&self, multiplicity: f64) -> f64 {
        self.atomic * multiplicity.max(0.0)
    }

    /// Cycles of a lockstep binary search over a window of `window`
    /// positions: `ceil(log2 window)` compare + dependent-load steps
    /// (mirrors the `BinarySearchBefore` charge in `sim::exec`). Returns
    /// `(cycles, dependent_sectors)`.
    pub fn bsearch(&self, window: f64) -> (f64, f64) {
        let steps = window.max(1.0).log2().ceil().max(0.0);
        (self.bsearch_step * steps, steps)
    }
}

/// Accumulated cost of one warp's execution.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct WarpCost {
    /// Issue/ALU/shuffle/atomic cycles on the SM.
    pub compute_cycles: f64,
    /// Distinct 32-byte DRAM sectors touched.
    pub sectors: u64,
    /// Number of serialized atomic updates (for reporting).
    pub atomic_updates: u64,
    /// Warp-instructions executed (for reporting / roofline).
    pub instructions: u64,
}

impl WarpCost {
    pub fn add_alu(&mut self, p: &CostParams, n: f64) {
        self.compute_cycles += p.alu * n;
        self.instructions += 1;
    }

    pub fn add_load(&mut self, p: &CostParams, sectors: u64) {
        self.compute_cycles += p.load_issue;
        self.sectors += sectors;
        self.instructions += 1;
    }

    pub fn add_atomics(&mut self, p: &CostParams, serialized: u64) {
        self.compute_cycles += p.atomic * serialized as f64;
        self.atomic_updates += serialized;
        self.instructions += 1;
    }

    pub fn add_group_reduce(&mut self, p: &CostParams, r: u32, shfl_per_step: f64) {
        self.compute_cycles += p.group_reduce(r, shfl_per_step);
        self.instructions += 1;
    }

    pub fn merge(&mut self, other: &WarpCost) {
        self.compute_cycles += other.compute_cycles;
        self.sectors += other.sectors;
        self.atomic_updates += other.atomic_updates;
        self.instructions += other.instructions;
    }
}

/// Count distinct 32-byte sectors for a set of element addresses.
/// `elem_size` is the element width in bytes (4 for f32/i32).
pub fn distinct_sectors(addrs: impl Iterator<Item = usize>, elem_size: usize) -> u64 {
    let mut sectors: Vec<usize> = addrs.map(|a| a * elem_size / 32).collect();
    sectors.sort_unstable();
    sectors.dedup();
    sectors.len() as u64
}

/// Serialization count for atomics: sum over distinct addresses of
/// (multiplicity), i.e. every conflicting update costs one atomic slot.
pub fn atomic_serialization(addrs: impl Iterator<Item = usize>) -> u64 {
    addrs.count() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn group_reduce_monotone_in_r() {
        let p = CostParams::default();
        let c4 = p.group_reduce(4, 1.0);
        let c8 = p.group_reduce(8, 1.0);
        let c32 = p.group_reduce(32, 1.0);
        assert!(c4 < c8 && c8 < c32);
        // width-proportional convergence makes 32 much more than log-scaled 8
        assert!(c32 / c8 > 5.0 / 3.0, "c32={c32} c8={c8}");
    }

    #[test]
    fn coalesced_loads_one_sector_per_8_f32() {
        // 32 consecutive f32 = 128 bytes = 4 sectors
        assert_eq!(distinct_sectors(0..32, 4), 4);
        // 32 strided (stride 16) f32 touch 32 different sectors
        assert_eq!(distinct_sectors((0..32).map(|i| i * 16), 4), 32);
        // all lanes same address = 1 sector
        assert_eq!(distinct_sectors(std::iter::repeat_n(7usize, 32), 4), 1);
    }

    #[test]
    fn analytic_helpers_mirror_the_interpreter_charges() {
        let p = CostParams::default();
        assert_eq!(p.par_reduce(8), p.group_reduce(8, 1.0));
        assert_eq!(p.seg_scan(8), p.group_reduce(8, 2.0));
        assert!(p.seg_scan(8) > p.par_reduce(8), "scan carries key + value");
        assert_eq!(p.atomic_chain(3.0), p.atomic * 3.0);
        assert_eq!(p.atomic_chain(-1.0), 0.0);
        let (cy, sec) = p.bsearch(64.0);
        assert_eq!(sec, 6.0);
        assert_eq!(cy, p.bsearch_step * 6.0);
        assert_eq!(p.bsearch(1.0).1, 0.0);
    }

    #[test]
    fn params_round_trip_through_the_calibration_vector() {
        let p = CostParams::default();
        let v = p.to_array();
        assert_eq!(v.len(), CostParams::N);
        assert_eq!(CostParams::NAMES.len(), CostParams::N);
        let q = CostParams::from_array(v);
        assert_eq!(q.to_array(), v);
        // every named slot is live: perturbing slot i changes only field i
        for i in 0..CostParams::N {
            let mut w = v;
            w[i] *= 2.0;
            let r = CostParams::from_array(w);
            assert_eq!(r.to_array(), w, "slot {} ({})", i, CostParams::NAMES[i]);
        }
    }

    #[test]
    fn warp_cost_accumulates() {
        let p = CostParams::default();
        let mut w = WarpCost::default();
        w.add_alu(&p, 3.0);
        w.add_load(&p, 4);
        w.add_atomics(&p, 2);
        assert_eq!(w.sectors, 4);
        assert_eq!(w.atomic_updates, 2);
        assert!(w.compute_cycles > 0.0);
        assert_eq!(w.instructions, 3);
    }
}
