//! Device global memory: named, typed buffers with bounds checking.
//!
//! Buffers live in a dense table; the resolve pass (`sim::resolve`) turns
//! kernel array names into table ids once per launch so the interpreter's
//! hot loop never hashes strings.

use std::collections::HashMap;

use thiserror::Error;

#[derive(Debug, Error)]
pub enum MemError {
    #[error("unknown buffer `{0}`")]
    UnknownBuffer(String),
    #[error("buffer `{name}` index {idx} out of bounds (len {len})")]
    OutOfBounds { name: String, idx: i64, len: usize },
    #[error("buffer `{0}` has the wrong element type for this access")]
    TypeMismatch(String),
}

/// A device buffer.
#[derive(Debug, Clone, PartialEq)]
pub enum Buffer {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Buffer {
    pub fn len(&self) -> usize {
        match self {
            Buffer::F32(v) => v.len(),
            Buffer::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Global memory: a table of named buffers plus grid-uniform i32 params.
#[derive(Debug, Default, Clone)]
pub struct DeviceMemory {
    ids: HashMap<String, usize>,
    names: Vec<String>,
    buffers: Vec<Buffer>,
    scalars: HashMap<String, i64>,
}

impl DeviceMemory {
    pub fn new() -> Self {
        Self::default()
    }

    fn bind(&mut self, name: &str, buf: Buffer) -> &mut Self {
        if let Some(&id) = self.ids.get(name) {
            self.buffers[id] = buf;
        } else {
            let id = self.buffers.len();
            self.ids.insert(name.to_string(), id);
            self.names.push(name.to_string());
            self.buffers.push(buf);
        }
        self
    }

    pub fn bind_f32(&mut self, name: &str, data: Vec<f32>) -> &mut Self {
        self.bind(name, Buffer::F32(data))
    }

    pub fn bind_i32(&mut self, name: &str, data: Vec<i32>) -> &mut Self {
        self.bind(name, Buffer::I32(data))
    }

    pub fn bind_scalar(&mut self, name: &str, v: i64) -> &mut Self {
        self.scalars.insert(name.into(), v);
        self
    }

    pub fn scalar(&self, name: &str) -> Result<i64, MemError> {
        self.scalars.get(name).copied().ok_or_else(|| MemError::UnknownBuffer(name.into()))
    }

    // ---- id-based fast path (resolved kernels) ---------------------------

    pub fn id_of(&self, name: &str) -> Option<usize> {
        self.ids.get(name).copied()
    }

    pub fn is_int_id(&self, id: usize) -> bool {
        matches!(self.buffers[id], Buffer::I32(_))
    }

    fn oob(&self, id: usize, idx: i64) -> MemError {
        MemError::OutOfBounds { name: self.names[id].clone(), idx, len: self.buffers[id].len() }
    }

    /// Load as f64 regardless of element type (ints promote losslessly).
    #[inline]
    pub fn load_num_id(&self, id: usize, idx: i64) -> Result<f64, MemError> {
        match &self.buffers[id] {
            Buffer::F32(v) => match v.get(usize::try_from(idx).map_err(|_| self.oob(id, idx))?) {
                Some(x) => Ok(*x as f64),
                None => Err(self.oob(id, idx)),
            },
            Buffer::I32(v) => match v.get(usize::try_from(idx).map_err(|_| self.oob(id, idx))?) {
                Some(x) => Ok(*x as f64),
                None => Err(self.oob(id, idx)),
            },
        }
    }

    #[inline]
    pub fn load_i_id(&self, id: usize, idx: i64) -> Result<i64, MemError> {
        match &self.buffers[id] {
            Buffer::I32(v) => match v.get(usize::try_from(idx).map_err(|_| self.oob(id, idx))?) {
                Some(x) => Ok(*x as i64),
                None => Err(self.oob(id, idx)),
            },
            Buffer::F32(_) => Err(MemError::TypeMismatch(self.names[id].clone())),
        }
    }

    #[inline]
    pub fn store_f_id(&mut self, id: usize, idx: i64, val: f32) -> Result<(), MemError> {
        match &mut self.buffers[id] {
            Buffer::F32(v) => {
                let len = v.len();
                match usize::try_from(idx).ok().and_then(|i| v.get_mut(i)) {
                    Some(slot) => {
                        *slot = val;
                        Ok(())
                    }
                    None => Err(MemError::OutOfBounds { name: self.names[id].clone(), idx, len }),
                }
            }
            Buffer::I32(_) => Err(MemError::TypeMismatch(self.names[id].clone())),
        }
    }

    #[inline]
    pub fn atomic_add_f_id(&mut self, id: usize, idx: i64, val: f32) -> Result<(), MemError> {
        match &mut self.buffers[id] {
            Buffer::F32(v) => {
                let len = v.len();
                match usize::try_from(idx).ok().and_then(|i| v.get_mut(i)) {
                    Some(slot) => {
                        *slot += val;
                        Ok(())
                    }
                    None => Err(MemError::OutOfBounds { name: self.names[id].clone(), idx, len }),
                }
            }
            Buffer::I32(_) => Err(MemError::TypeMismatch(self.names[id].clone())),
        }
    }

    // ---- name-based API (setup / extraction / tests) ---------------------

    pub fn buffer(&self, name: &str) -> Result<&Buffer, MemError> {
        self.id_of(name)
            .map(|id| &self.buffers[id])
            .ok_or_else(|| MemError::UnknownBuffer(name.into()))
    }

    pub fn is_int_buffer(&self, name: &str) -> Result<bool, MemError> {
        Ok(matches!(self.buffer(name)?, Buffer::I32(_)))
    }

    pub fn load_num(&self, name: &str, idx: i64) -> Result<f64, MemError> {
        let id = self.id_of(name).ok_or_else(|| MemError::UnknownBuffer(name.into()))?;
        self.load_num_id(id, idx)
    }

    pub fn load_i(&self, name: &str, idx: i64) -> Result<i64, MemError> {
        let id = self.id_of(name).ok_or_else(|| MemError::UnknownBuffer(name.into()))?;
        self.load_i_id(id, idx)
    }

    pub fn store_f(&mut self, name: &str, idx: i64, val: f32) -> Result<(), MemError> {
        let id = self.id_of(name).ok_or_else(|| MemError::UnknownBuffer(name.into()))?;
        self.store_f_id(id, idx, val)
    }

    pub fn atomic_add_f(&mut self, name: &str, idx: i64, val: f32) -> Result<(), MemError> {
        let id = self.id_of(name).ok_or_else(|| MemError::UnknownBuffer(name.into()))?;
        self.atomic_add_f_id(id, idx, val)
    }

    pub fn take_f32(&mut self, name: &str) -> Option<Vec<f32>> {
        let id = self.id_of(name)?;
        match std::mem::replace(&mut self.buffers[id], Buffer::F32(Vec::new())) {
            Buffer::F32(v) => Some(v),
            other => {
                self.buffers[id] = other;
                None
            }
        }
    }

    pub fn f32_slice(&self, name: &str) -> Result<&[f32], MemError> {
        match self.buffer(name)? {
            Buffer::F32(v) => Ok(v),
            Buffer::I32(_) => Err(MemError::TypeMismatch(name.into())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bind_load_store() {
        let mut m = DeviceMemory::new();
        m.bind_f32("x", vec![1.0, 2.0]).bind_i32("p", vec![0, 3]).bind_scalar("n", 2);
        assert_eq!(m.load_num("x", 1).unwrap(), 2.0);
        assert_eq!(m.load_i("p", 1).unwrap(), 3);
        assert_eq!(m.scalar("n").unwrap(), 2);
        m.store_f("x", 0, 9.0).unwrap();
        assert_eq!(m.f32_slice("x").unwrap(), &[9.0, 2.0]);
        m.atomic_add_f("x", 0, 1.0).unwrap();
        assert_eq!(m.f32_slice("x").unwrap()[0], 10.0);
    }

    #[test]
    fn bounds_checked() {
        let mut m = DeviceMemory::new();
        m.bind_f32("x", vec![0.0; 4]);
        assert!(matches!(m.load_num("x", 4), Err(MemError::OutOfBounds { .. })));
        assert!(matches!(m.load_num("x", -1), Err(MemError::OutOfBounds { .. })));
        assert!(matches!(m.load_num("y", 0), Err(MemError::UnknownBuffer(_))));
    }

    #[test]
    fn type_checked() {
        let mut m = DeviceMemory::new();
        m.bind_i32("p", vec![1]);
        assert!(matches!(m.store_f("p", 0, 1.0), Err(MemError::TypeMismatch(_))));
        assert!(matches!(m.load_i("p", 0), Ok(1)));
    }

    #[test]
    fn rebind_keeps_id() {
        let mut m = DeviceMemory::new();
        m.bind_f32("x", vec![1.0]);
        let id = m.id_of("x").unwrap();
        m.bind_f32("x", vec![2.0, 3.0]);
        assert_eq!(m.id_of("x").unwrap(), id);
        assert_eq!(m.f32_slice("x").unwrap(), &[2.0, 3.0]);
    }

    #[test]
    fn id_fast_path_matches_name_path() {
        let mut m = DeviceMemory::new();
        m.bind_i32("p", vec![7, 8]);
        let id = m.id_of("p").unwrap();
        assert!(m.is_int_id(id));
        assert_eq!(m.load_i_id(id, 1).unwrap(), 8);
        assert!(m.load_i_id(id, 9).is_err());
    }
}
