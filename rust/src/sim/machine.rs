//! SM-level scheduling + hardware profiles: rolls per-warp costs up to an
//! estimated kernel time on a named GPU.

use anyhow::Result;

use crate::compiler::llir::Kernel;

use super::cost::{CostParams, WarpCost};
use super::exec::WarpExecutor;
use super::memory::DeviceMemory;

/// A GPU hardware profile (§7 experiment settings).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HwProfile {
    pub name: &'static str,
    pub sm_count: u32,
    pub clock_ghz: f64,
    pub dram_gbps: f64,
    /// Warp instructions issued per cycle per SM (schedulers).
    pub issue_width: f64,
    /// Fixed kernel launch overhead (seconds).
    pub launch_overhead_s: f64,
}

impl HwProfile {
    // Launch overhead is nonzero on every preset: the paper measures
    // *kernel duration* with nsight-compute (§7), which excludes the
    // host-side launch path but still pays the front-end drain/setup of
    // each launch — and a zero here made every multi-launch plan
    // (per-band composites, the two-stage SDDMM→SpMM pipeline) price its
    // extra launches for free, biasing the selector toward them. The
    // seeded values are scaled to the reduced-size simulation suite
    // (whose kernel bodies sit in the 0.1–2 µs range) and, like
    // `CostParams`, are calibratable: `tuner::calibrate` fits
    // `launch_overhead_s` alongside the per-instruction charges.

    /// NVIDIA RTX 3090: 68 Ampere SMs @ 1.395 GHz, 936 GB/s GDDR6X.
    pub fn rtx3090() -> Self {
        HwProfile { name: "RTX 3090", sm_count: 68, clock_ghz: 1.395, dram_gbps: 936.0, issue_width: 4.0, launch_overhead_s: 2.0e-8 }
    }
    /// NVIDIA RTX 2080: 46 Turing SMs @ 1.515 GHz, 448 GB/s GDDR6.
    pub fn rtx2080() -> Self {
        HwProfile { name: "RTX 2080", sm_count: 46, clock_ghz: 1.515, dram_gbps: 448.0, issue_width: 4.0, launch_overhead_s: 2.5e-8 }
    }
    /// NVIDIA Tesla V100: 80 Volta SMs @ 1.370 GHz, 900 GB/s HBM2.
    pub fn v100() -> Self {
        HwProfile { name: "Tesla V100", sm_count: 80, clock_ghz: 1.370, dram_gbps: 900.0, issue_width: 4.0, launch_overhead_s: 2.2e-8 }
    }

    pub fn all() -> Vec<HwProfile> {
        vec![Self::rtx3090(), Self::rtx2080(), Self::v100()]
    }
}

/// Result of a simulated kernel launch.
#[derive(Debug, Clone)]
pub struct KernelReport {
    pub hw: HwProfile,
    pub grid: u32,
    pub block_dim: u32,
    pub warps: u64,
    /// Aggregate over all warps.
    pub total: WarpCost,
    /// Critical path: the most expensive single warp (cycles).
    pub max_warp_cycles: f64,
    /// Estimated execution time in seconds.
    pub time_s: f64,
    /// Which bound dominated: "compute", "memory", or "latency".
    pub bound: &'static str,
}

impl KernelReport {
    pub fn gflops(&self, flops: u64) -> f64 {
        flops as f64 / self.time_s / 1e9
    }
}

/// A simulated GPU: executes LLIR kernels, charging the cost model.
#[derive(Debug, Clone)]
pub struct Machine {
    pub hw: HwProfile,
    pub params: CostParams,
}

impl Machine {
    pub fn new(hw: HwProfile) -> Self {
        Machine { hw, params: CostParams::default() }
    }

    /// Launch `kernel` over `grid` blocks against `mem`.
    ///
    /// Executes every warp (numerics are exact), accumulates costs, then
    /// applies the roofline roll-up:
    ///
    /// `time = max(compute cycles per SM / issue width, DRAM bytes / BW,
    ///             critical warp) + launch overhead`
    pub fn launch(&self, kernel: &Kernel, grid: u32, mem: &mut DeviceMemory) -> Result<KernelReport> {
        // resolve once per launch: slot vars, array ids, inlined params
        let resolved = super::resolve::resolve(kernel, mem)
            .map_err(|e| anyhow::anyhow!("kernel `{}`: {e}", kernel.name))?;
        let warps_per_block = kernel.block_dim.div_ceil(32);
        let mut sm_cycles = vec![0f64; self.hw.sm_count as usize];
        let mut total = WarpCost::default();
        let mut max_warp_cycles = 0f64;
        let mut warps = 0u64;

        for block in 0..grid {
            let sm = (block % self.hw.sm_count) as usize;
            for w in 0..warps_per_block {
                let mut ex = WarpExecutor::new(mem, &self.params, block, w, kernel.block_dim);
                ex.run(&resolved).map_err(|e| {
                    anyhow::anyhow!("kernel `{}` block {block} warp {w}: {e}", kernel.name)
                })?;
                let c = ex.cost;
                sm_cycles[sm] += c.compute_cycles;
                max_warp_cycles = max_warp_cycles.max(c.compute_cycles);
                total.merge(&c);
                warps += 1;
            }
        }

        let clock_hz = self.hw.clock_ghz * 1e9;
        let t_compute = sm_cycles.iter().cloned().fold(0f64, f64::max) / self.hw.issue_width / clock_hz;
        let t_memory = (total.sectors as f64 * 32.0) / (self.hw.dram_gbps * 1e9);
        let t_latency = max_warp_cycles / clock_hz;
        let body = t_compute.max(t_memory).max(t_latency);
        let bound = if body == t_compute {
            "compute"
        } else if body == t_memory {
            "memory"
        } else {
            "latency"
        };

        Ok(KernelReport {
            hw: self.hw,
            grid,
            block_dim: kernel.block_dim,
            warps,
            total,
            max_warp_cycles,
            time_s: body + self.hw.launch_overhead_s,
            bound,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::llir::{Param, Stmt, Val};

    fn copy_kernel() -> Kernel {
        // out[tid + blockIdx*block] = in[...] * 2
        let gid = Val::add(Val::mul(Val::BlockIdx, Val::ConstI(64)), Val::ThreadIdx);
        Kernel {
            name: "copy".into(),
            params: vec![Param::f32_array("in"), Param::f32_array("out")],
            body: vec![Stmt::Store {
                array: "out".into(),
                idx: gid.clone(),
                val: Val::mul(Val::load("in", gid), Val::ConstF(2.0)),
            }],
            block_dim: 64,
        }
    }

    #[test]
    fn launch_runs_all_blocks() {
        let m = Machine::new(HwProfile::rtx3090());
        let mut mem = DeviceMemory::new();
        mem.bind_f32("in", (0..256).map(|i| i as f32).collect());
        mem.bind_f32("out", vec![0.0; 256]);
        let rep = m.launch(&copy_kernel(), 4, &mut mem).unwrap();
        assert_eq!(rep.warps, 8);
        let out = mem.f32_slice("out").unwrap();
        assert_eq!(out[100], 200.0);
        assert!(rep.time_s > 0.0);
        assert!(rep.total.sectors >= 64); // 256 loads + 256 stores coalesced
    }

    #[test]
    fn profiles_distinct() {
        let a = HwProfile::rtx3090();
        let b = HwProfile::rtx2080();
        assert!(a.dram_gbps > b.dram_gbps);
        assert_eq!(HwProfile::all().len(), 3);
    }

    #[test]
    fn presets_charge_nonzero_launch_overhead() {
        for hw in HwProfile::all() {
            assert!(
                hw.launch_overhead_s > 0.0,
                "{}: multi-launch plans must not get their extra launches for free",
                hw.name
            );
            // scaled to the reduced-size suite: well below the smallest
            // simulated kernel bodies (~0.1 us), so single-launch ranking
            // is a constant shift, not a reordering
            assert!(hw.launch_overhead_s < 1.0e-7, "{}", hw.name);
        }
    }

    #[test]
    fn memory_bound_scales_with_bandwidth() {
        // same kernel, slower DRAM => slower (it's memory bound)
        let mut fast = Machine::new(HwProfile::rtx3090());
        fast.hw.launch_overhead_s = 0.0;
        let mut slow = Machine::new(HwProfile::rtx2080());
        slow.hw.launch_overhead_s = 0.0;
        let run = |m: &Machine| {
            let mut mem = DeviceMemory::new();
            mem.bind_f32("in", vec![1.0; 1 << 16]);
            mem.bind_f32("out", vec![0.0; 1 << 16]);
            m.launch(&copy_kernel(), (1 << 16) / 64, &mut mem).unwrap()
        };
        let rf = run(&fast);
        let rs = run(&slow);
        assert_eq!(rf.bound, "memory");
        assert!(rs.time_s > rf.time_s);
    }
}
