//! Resolve pass: LLIR → slot-indexed executable form.
//!
//! The interpreter's hot loop must not hash strings. This pass runs once
//! per launch and rewrites the kernel so that
//!
//! * local variables become indices into a dense slot vector,
//! * array names become buffer ids into [`DeviceMemory`]'s buffer table,
//! * grid-uniform scalar params (`A1_dimension`, …) are **inlined as
//!   integer constants** (they cannot change during a launch).
//!
//! Added in the §Perf pass — see EXPERIMENTS.md §Perf for before/after.

use thiserror::Error;

use crate::compiler::llir::{BinOp, Kernel, Stmt, Val};

use super::memory::DeviceMemory;

#[derive(Debug, Error)]
pub enum ResolveError {
    #[error("kernel references unbound array `{0}`")]
    UnknownArray(String),
    #[error("kernel references unbound scalar param `{0}`")]
    UnknownScalar(String),
}

/// Resolved value expression.
#[derive(Debug, Clone, PartialEq)]
pub enum RVal {
    Var(u16),
    ConstI(i64),
    ConstF(f32),
    Bin(BinOp, Box<RVal>, Box<RVal>),
    /// `buffers[id][idx]`; `int` caches the element type.
    Load { array: u16, int: bool, idx: Box<RVal> },
    BinarySearchBefore { array: u16, lo: Box<RVal>, hi: Box<RVal>, target: Box<RVal> },
    BlockIdx,
    ThreadIdx,
}

/// Resolved statement.
#[derive(Debug, Clone, PartialEq)]
pub enum RStmt {
    Decl { var: u16, init: RVal, float: bool },
    /// `float` mirrors the Decl that introduced the var.
    Assign { var: u16, val: RVal, float: bool },
    Store { array: u16, idx: RVal, val: RVal },
    AtomicAdd { array: u16, idx: RVal, val: RVal },
    AtomicAddGroup { array: u16, idx: RVal, val: RVal, group: u32 },
    SegReduceGroup { array: u16, idx: RVal, val: RVal, group: u32 },
    For { var: u16, lo: RVal, hi: RVal, step: RVal, body: Vec<RStmt> },
    While { cond: RVal, body: Vec<RStmt> },
    If { cond: RVal, then: Vec<RStmt>, els: Vec<RStmt> },
    Break,
}

/// A launch-ready kernel.
#[derive(Debug, Clone)]
pub struct ResolvedKernel {
    pub name: String,
    pub body: Vec<RStmt>,
    pub block_dim: u32,
    /// Number of local-variable slots.
    pub slots: u16,
}

struct Resolver<'m> {
    mem: &'m DeviceMemory,
    vars: Vec<String>,
    floats: Vec<bool>,
}

impl<'m> Resolver<'m> {
    fn var_slot(&mut self, name: &str) -> u16 {
        if let Some(i) = self.vars.iter().position(|v| v == name) {
            i as u16
        } else {
            self.vars.push(name.to_string());
            self.floats.push(false);
            (self.vars.len() - 1) as u16
        }
    }

    fn array_id(&self, name: &str) -> Result<(u16, bool), ResolveError> {
        let id = self
            .mem
            .id_of(name)
            .ok_or_else(|| ResolveError::UnknownArray(name.to_string()))?;
        Ok((id as u16, self.mem.is_int_id(id)))
    }

    fn val(&mut self, v: &Val) -> Result<RVal, ResolveError> {
        Ok(match v {
            Val::Var(n) => RVal::Var(self.var_slot(n)),
            Val::ConstI(c) => RVal::ConstI(*c),
            Val::ConstF(c) => RVal::ConstF(*c),
            Val::Param(n) => RVal::ConstI(
                self.mem.scalar(n).map_err(|_| ResolveError::UnknownScalar(n.clone()))?,
            ),
            Val::Bin(op, a, b) => RVal::Bin(*op, Box::new(self.val(a)?), Box::new(self.val(b)?)),
            Val::Load(a, idx) => {
                let (array, int) = self.array_id(a)?;
                RVal::Load { array, int, idx: Box::new(self.val(idx)?) }
            }
            Val::BinarySearchBefore { array, lo, hi, target } => {
                let (array, _) = self.array_id(array)?;
                RVal::BinarySearchBefore {
                    array,
                    lo: Box::new(self.val(lo)?),
                    hi: Box::new(self.val(hi)?),
                    target: Box::new(self.val(target)?),
                }
            }
            Val::BlockIdx => RVal::BlockIdx,
            Val::ThreadIdx => RVal::ThreadIdx,
        })
    }

    fn stmts(&mut self, stmts: &[Stmt]) -> Result<Vec<RStmt>, ResolveError> {
        let mut out = Vec::with_capacity(stmts.len());
        for s in stmts {
            match s {
                Stmt::Comment(_) => {}
                Stmt::Decl { var, init, float } => {
                    let init = self.val(init)?;
                    let slot = self.var_slot(var);
                    self.floats[slot as usize] = *float;
                    out.push(RStmt::Decl { var: slot, init, float: *float });
                }
                Stmt::Assign { var, val } => {
                    let val = self.val(val)?;
                    let slot = self.var_slot(var);
                    let float = self.floats[slot as usize];
                    out.push(RStmt::Assign { var: slot, val, float });
                }
                Stmt::Store { array, idx, val } => {
                    let (array, _) = self.array_id(array)?;
                    out.push(RStmt::Store { array, idx: self.val(idx)?, val: self.val(val)? });
                }
                Stmt::AtomicAdd { array, idx, val } => {
                    let (array, _) = self.array_id(array)?;
                    out.push(RStmt::AtomicAdd { array, idx: self.val(idx)?, val: self.val(val)? });
                }
                Stmt::AtomicAddGroup { array, idx, val, group } => {
                    let (array, _) = self.array_id(array)?;
                    out.push(RStmt::AtomicAddGroup {
                        array,
                        idx: self.val(idx)?,
                        val: self.val(val)?,
                        group: *group,
                    });
                }
                Stmt::SegReduceGroup { array, idx, val, group } => {
                    let (array, _) = self.array_id(array)?;
                    out.push(RStmt::SegReduceGroup {
                        array,
                        idx: self.val(idx)?,
                        val: self.val(val)?,
                        group: *group,
                    });
                }
                Stmt::For { var, lo, hi, step, body } => {
                    let lo = self.val(lo)?;
                    let hi = self.val(hi)?;
                    let step = self.val(step)?;
                    let slot = self.var_slot(var);
                    out.push(RStmt::For { var: slot, lo, hi, step, body: self.stmts(body)? });
                }
                Stmt::While { cond, body } => {
                    out.push(RStmt::While { cond: self.val(cond)?, body: self.stmts(body)? });
                }
                Stmt::If { cond, then, els } => out.push(RStmt::If {
                    cond: self.val(cond)?,
                    then: self.stmts(then)?,
                    els: self.stmts(els)?,
                }),
                Stmt::Break => out.push(RStmt::Break),
            }
        }
        Ok(out)
    }
}

/// Resolve a kernel against bound memory (arrays + scalars must be bound).
pub fn resolve(kernel: &Kernel, mem: &DeviceMemory) -> Result<ResolvedKernel, ResolveError> {
    let mut r = Resolver { mem, vars: Vec::new(), floats: Vec::new() };
    let body = r.stmts(&kernel.body)?;
    Ok(ResolvedKernel {
        name: kernel.name.clone(),
        body,
        block_dim: kernel.block_dim,
        slots: r.vars.len() as u16,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::llir::Param;

    #[test]
    fn params_inline_and_vars_slot() {
        let k = Kernel {
            name: "t".into(),
            params: vec![Param::f32_array("x"), Param::i32_scalar("n")],
            block_dim: 32,
            body: vec![
                Stmt::Decl { var: "a".into(), init: Val::param("n"), float: false },
                Stmt::Assign { var: "a".into(), val: Val::add(Val::var("a"), Val::ConstI(1)) },
                Stmt::Store { array: "x".into(), idx: Val::var("a"), val: Val::ConstF(1.0) },
            ],
        };
        let mut mem = DeviceMemory::new();
        mem.bind_f32("x", vec![0.0; 8]).bind_scalar("n", 5);
        let r = resolve(&k, &mem).unwrap();
        assert_eq!(r.slots, 1);
        match &r.body[0] {
            RStmt::Decl { init: RVal::ConstI(5), .. } => {}
            other => panic!("param not inlined: {other:?}"),
        }
    }

    #[test]
    fn unknown_array_errors() {
        let k = Kernel {
            name: "t".into(),
            params: vec![],
            block_dim: 32,
            body: vec![Stmt::Store { array: "nope".into(), idx: Val::ConstI(0), val: Val::ConstF(0.0) }],
        };
        let mem = DeviceMemory::new();
        assert!(matches!(resolve(&k, &mem), Err(ResolveError::UnknownArray(_))));
    }
}
