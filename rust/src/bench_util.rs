//! Shared bench harness (criterion is not in the offline dependency set;
//! the benches are `harness = false` binaries that print paper-style
//! tables and assert the headline *shape* holds).

use crate::sparse::{dataset, DatasetSpec, SplitMix64};

/// Geometric mean (the paper's aggregation for speedups, Table 4 note 1).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|x| x.ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Normalized speedup of A over B (§7.1): if A beats B count the speedup,
/// otherwise assume the user picks the better algorithm and count 1.0.
pub fn normalized_speedup(t_a: f64, t_b: f64) -> f64 {
    (t_b / t_a).max(1.0)
}

/// Raw speedup of A over B.
pub fn speedup(t_a: f64, t_b: f64) -> f64 {
    t_b / t_a
}

/// Random dense B, deterministic per seed.
pub fn random_b(cols: usize, n: usize, seed: u64) -> Vec<f32> {
    let mut rng = SplitMix64::new(seed);
    (0..cols * n).map(|_| rng.value()).collect()
}

/// The bench subset of the evaluation suite: one representative per
/// family/size point (12 matrices) so every table finishes in minutes.
/// `examples/fig11_sweep.rs` runs the full suite.
pub fn bench_suite() -> Vec<DatasetSpec> {
    let keep = [
        "er_1024_d1e-3",
        "er_1024_d2e-2",
        "er_2048_d2e-3",
        "er_4096_d1e-4",
        "pl_1024_a1.8",
        "pl_2048_a1.6",
        "pl_4096_a2",
        "band_1024_w5",
        "band_2048_w9",
        "block_2048_b16",
        "corner_short_rows_2048",
        "corner_hub_1024",
    ];
    let out: Vec<DatasetSpec> =
        dataset::suite().into_iter().filter(|d| keep.contains(&d.name.as_str())).collect();
    assert!(out.len() >= 10, "bench suite unexpectedly small: {}", out.len());
    out
}

/// The dgSPARSE-sweep subset (tables 4/5): the bench suite minus the
/// 4096-row matrices. Those tables sweep N up to 128 (32× the N=4 work)
/// over ~20 configs × 3 profiles on the CI box's single core; the smaller
/// matrices keep the sweep under ten minutes while preserving the
/// density/skew span.
pub fn bench_suite_small() -> Vec<DatasetSpec> {
    bench_suite().into_iter().filter(|d| d.matrix.rows < 4096).collect()
}

/// Fixed-width table printer.
pub struct Table {
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let parts: Vec<String> =
                cells.iter().enumerate().map(|(i, c)| format!("{:<w$}", c, w = widths[i])).collect();
            println!("| {} |", parts.join(" | "));
        };
        line(&self.headers);
        println!(
            "|{}|",
            widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("|")
        );
        for row in &self.rows {
            line(row);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_of_constant() {
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn normalized_clamps_at_one() {
        assert_eq!(normalized_speedup(2.0, 1.0), 1.0); // A slower: count 1
        assert_eq!(normalized_speedup(1.0, 2.0), 2.0);
    }

    #[test]
    fn bench_suite_spans_families() {
        let s = bench_suite();
        let fams: std::collections::HashSet<&str> = s.iter().map(|d| d.family).collect();
        assert!(fams.len() >= 4, "families: {fams:?}");
    }
}
