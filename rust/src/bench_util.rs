//! Shared bench harness (criterion is not in the offline dependency set;
//! the benches are `harness = false` binaries that print paper-style
//! tables and assert the headline *shape* holds) — plus the machine-
//! readable side: [`BenchReport`], the versioned `BENCH_*.json` writer
//! behind `sgap bench`, and [`validate_bench_json`], the schema gate CI
//! and the tests both enforce (EXPERIMENTS.md §BENCH documents the
//! schema).

use std::path::Path;

use anyhow::{Context, Result};

use crate::algos::catalog::{c_values, Algo};
use crate::algos::dgsparse::DgConfig;
use crate::algos::mttkrp::{MttkrpConfig, TtmConfig};
use crate::runtime::json::Json;
use crate::sim::Machine;
use crate::sparse::{dataset, gen, Coo3, DatasetSpec, MatrixStats, SplitMix64};
use crate::tuner::calibrate::{self, Calibration, Sample, WorkloadSpec};
use crate::tuner::{self, CostModel, PrunedOutcome, Selector, Workload};

/// Geometric mean (the paper's aggregation for speedups, Table 4 note 1).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|x| x.ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Normalized speedup of A over B (§7.1): if A beats B count the speedup,
/// otherwise assume the user picks the better algorithm and count 1.0.
pub fn normalized_speedup(t_a: f64, t_b: f64) -> f64 {
    (t_b / t_a).max(1.0)
}

/// Raw speedup of A over B.
pub fn speedup(t_a: f64, t_b: f64) -> f64 {
    t_b / t_a
}

/// Random dense B, deterministic per seed.
pub fn random_b(cols: usize, n: usize, seed: u64) -> Vec<f32> {
    let mut rng = SplitMix64::new(seed);
    (0..cols * n).map(|_| rng.value()).collect()
}

/// The bench subset of the evaluation suite: one representative per
/// family/size point (12 matrices) so every table finishes in minutes.
/// `examples/fig11_sweep.rs` runs the full suite.
pub fn bench_suite() -> Vec<DatasetSpec> {
    let keep = [
        "er_1024_d1e-3",
        "er_1024_d2e-2",
        "er_2048_d2e-3",
        "er_4096_d1e-4",
        "pl_1024_a1.8",
        "pl_2048_a1.6",
        "pl_4096_a2",
        "band_1024_w5",
        "band_2048_w9",
        "block_2048_b16",
        "corner_short_rows_2048",
        "corner_hub_1024",
    ];
    let out: Vec<DatasetSpec> =
        dataset::suite().into_iter().filter(|d| keep.contains(&d.name.as_str())).collect();
    assert!(out.len() >= 10, "bench suite unexpectedly small: {}", out.len());
    out
}

/// The skew suite: the high-CV matrices the band partitioner targets —
/// power-law at α ∈ {1.6, 2.0} and the block-community graph. Fixed (and
/// small) enough to run in `--quick` mode too, so the hybrid-vs-single
/// comparison is always in the committed report.
pub fn skew_suite() -> Vec<DatasetSpec> {
    let keep = ["pl_2048_a1.6", "pl_4096_a2", "block_2048_b16"];
    let out: Vec<DatasetSpec> =
        dataset::suite().into_iter().filter(|d| keep.contains(&d.name.as_str())).collect();
    assert_eq!(out.len(), 3, "skew suite drifted: {}", out.len());
    out
}

/// The fused-GNN suite: the matrices the fused SDDMM→SpMM table prices —
/// two graph-scale suite members (where fusion's one-traversal saving is
/// a small constant) plus one dense community block, `er_128_d2e-1`,
/// whose X2 footprint (128 columns < the 256-sector warp gather) lets a
/// fused warp cover twice the non-zeros of the best standalone SDDMM
/// under the same working set — the regime where fusion's headline
/// speedup lives. Fixed and analytic, so it runs in `--quick` too.
pub fn fused_suite() -> Vec<DatasetSpec> {
    let keep = ["er_2048_d2e-3", "band_2048_w9"];
    let mut out: Vec<DatasetSpec> =
        dataset::suite().into_iter().filter(|d| keep.contains(&d.name.as_str())).collect();
    out.push(DatasetSpec {
        name: "er_128_d2e-1".into(),
        family: "erdos_renyi",
        matrix: gen::erdos_renyi(128, 128, 3276, 77),
    });
    assert_eq!(out.len(), 3, "fused suite drifted: {}", out.len());
    out
}

/// The dgSPARSE-sweep subset (tables 4/5): the bench suite minus the
/// 4096-row matrices. Those tables sweep N up to 128 (32× the N=4 work)
/// over ~20 configs × 3 profiles on the CI box's single core; the smaller
/// matrices keep the sweep under ten minutes while preserving the
/// density/skew span.
pub fn bench_suite_small() -> Vec<DatasetSpec> {
    bench_suite().into_iter().filter(|d| d.matrix.rows < 4096).collect()
}

// ---------------------------------------------------------------------------
// machine-readable benchmark reports (`sgap bench` → BENCH_*.json)
// ---------------------------------------------------------------------------

/// Version stamp of the `BENCH_*.json` schema. Bump it (and the
/// EXPERIMENTS.md §BENCH table, and [`ROW_FIELDS`]/[`TOP_FIELDS`])
/// together — [`validate_bench_json`] fails on any drift.
pub const BENCH_SCHEMA_VERSION: u64 = 1;

/// Exactly the top-level keys a report carries.
pub const TOP_FIELDS: [&str; 9] = [
    "schema_version",
    "suite",
    "generator",
    "hw",
    "quick",
    "top_k",
    "geomean_speedup",
    "rank_agreement",
    "rows",
];

/// Exactly the keys every row carries.
pub const ROW_FIELDS: [&str; 13] = [
    "bench",
    "matrix",
    "family",
    "width",
    "algo",
    "baseline",
    "est_time_us",
    "baseline_time_us",
    "gflops",
    "speedup_vs_baseline",
    "model_rank_agree",
    "grid",
    "survivors",
];

/// One benchmark result: the pruned-tuned winner on one input vs the
/// paper's stock baseline, plus the pruning audit trail.
#[derive(Debug, Clone)]
pub struct BenchRow {
    /// Which table the row belongs to: `families` (tables 1/2),
    /// `dgsparse` (table 4), `skew` (the per-band hybrid), `fused` (the
    /// one-kernel SDDMM→SpMM chain), `mttkrp` or `ttm` (the §2.1
    /// quartet).
    pub bench: &'static str,
    pub matrix: String,
    pub family: String,
    /// Dense width (N, J or L).
    pub width: u32,
    /// Winner of the pruned sweep.
    pub algo: String,
    /// The stock configuration the speedup is measured against.
    pub baseline: String,
    pub est_time_us: f64,
    pub baseline_time_us: f64,
    pub gflops: f64,
    /// `baseline_time / est_time` (> 1 means tuning won).
    pub speedup_vs_baseline: f64,
    /// Did the analytic model's top-1 pick win the simulated shortlist?
    pub model_rank_agree: bool,
    /// Candidate-grid size before pruning / after (simulated survivors).
    pub grid: usize,
    pub survivors: usize,
}

/// A versioned, machine-readable benchmark report — the perf trajectory
/// every future PR moves. Serialized with a stable field order so diffs
/// of the committed `BENCH_*.json` stay reviewable.
#[derive(Debug, Clone)]
pub struct BenchReport {
    /// `"spmm"` or `"tensor"`.
    pub suite: &'static str,
    /// The exact invocation that regenerates this file.
    pub generator: String,
    pub hw: String,
    pub quick: bool,
    pub top_k: usize,
    pub rows: Vec<BenchRow>,
}

impl BenchReport {
    /// Geometric-mean speedup over the baseline (the headline number).
    pub fn geomean_speedup(&self) -> f64 {
        geomean(&self.rows.iter().map(|r| r.speedup_vs_baseline).collect::<Vec<_>>())
    }

    /// Fraction of rows where the model's top-1 pick won the simulation.
    pub fn rank_agreement(&self) -> f64 {
        if self.rows.is_empty() {
            return 0.0;
        }
        self.rows.iter().filter(|r| r.model_rank_agree).count() as f64 / self.rows.len() as f64
    }

    /// Serialize with stable key order and fixed-precision floats.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"schema_version\": {},\n", BENCH_SCHEMA_VERSION));
        out.push_str(&format!("  \"suite\": \"{}\",\n", esc(self.suite)));
        out.push_str(&format!("  \"generator\": \"{}\",\n", esc(&self.generator)));
        out.push_str(&format!("  \"hw\": \"{}\",\n", esc(&self.hw)));
        out.push_str(&format!("  \"quick\": {},\n", self.quick));
        out.push_str(&format!("  \"top_k\": {},\n", self.top_k));
        out.push_str(&format!("  \"geomean_speedup\": {:.4},\n", self.geomean_speedup()));
        out.push_str(&format!("  \"rank_agreement\": {:.4},\n", self.rank_agreement()));
        out.push_str("  \"rows\": [\n");
        for (i, r) in self.rows.iter().enumerate() {
            out.push_str("    {\n");
            out.push_str(&format!("      \"bench\": \"{}\",\n", esc(r.bench)));
            out.push_str(&format!("      \"matrix\": \"{}\",\n", esc(&r.matrix)));
            out.push_str(&format!("      \"family\": \"{}\",\n", esc(&r.family)));
            out.push_str(&format!("      \"width\": {},\n", r.width));
            out.push_str(&format!("      \"algo\": \"{}\",\n", esc(&r.algo)));
            out.push_str(&format!("      \"baseline\": \"{}\",\n", esc(&r.baseline)));
            out.push_str(&format!("      \"est_time_us\": {:.4},\n", r.est_time_us));
            out.push_str(&format!("      \"baseline_time_us\": {:.4},\n", r.baseline_time_us));
            out.push_str(&format!("      \"gflops\": {:.4},\n", r.gflops));
            out.push_str(&format!(
                "      \"speedup_vs_baseline\": {:.4},\n",
                r.speedup_vs_baseline
            ));
            out.push_str(&format!("      \"model_rank_agree\": {},\n", r.model_rank_agree));
            out.push_str(&format!("      \"grid\": {},\n", r.grid));
            out.push_str(&format!("      \"survivors\": {}\n", r.survivors));
            out.push_str(if i + 1 == self.rows.len() { "    }\n" } else { "    },\n" });
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Write to `path`, then re-validate what was written — the CLI and
    /// the blessed test both fail loudly if the emitted schema drifts
    /// from the documented one.
    pub fn write(&self, path: &Path) -> Result<()> {
        let json = self.to_json();
        validate_bench_json(&json, self.suite)
            .map_err(|e| anyhow::anyhow!("emitted {} report fails its own schema: {e}", self.suite))?;
        std::fs::write(path, &json).with_context(|| format!("writing {}", path.display()))?;
        Ok(())
    }
}

fn esc(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Validate a `BENCH_*.json` document against the documented schema:
/// exact top-level and row key sets, types, and the internal invariants
/// (positive times, `speedup = baseline/est`, summary fields consistent
/// with the rows). This is the drift gate: any field added, removed or
/// renamed without updating [`TOP_FIELDS`]/[`ROW_FIELDS`] fails here.
pub fn validate_bench_json(src: &str, expect_suite: &str) -> Result<(), String> {
    let doc = Json::parse(src).map_err(|e| e.to_string())?;
    let obj = doc.as_obj().ok_or("top level must be an object")?;
    let keys: Vec<&str> = obj.keys().map(String::as_str).collect();
    let mut want: Vec<&str> = TOP_FIELDS.to_vec();
    want.sort_unstable();
    if keys != want {
        return Err(format!("top-level keys {keys:?} != schema {want:?}"));
    }
    let ver = doc.get("schema_version").and_then(Json::as_f64).ok_or("schema_version")?;
    if ver as u64 != BENCH_SCHEMA_VERSION {
        return Err(format!("schema_version {ver} != {BENCH_SCHEMA_VERSION}"));
    }
    let suite = doc.get("suite").and_then(Json::as_str).ok_or("suite must be a string")?;
    if suite != expect_suite {
        return Err(format!("suite `{suite}` != expected `{expect_suite}`"));
    }
    doc.get("generator").and_then(Json::as_str).ok_or("generator must be a string")?;
    doc.get("hw").and_then(Json::as_str).ok_or("hw must be a string")?;
    if !matches!(doc.get("quick"), Some(Json::Bool(_))) {
        return Err("quick must be a bool".into());
    }
    doc.get("top_k").and_then(Json::as_f64).ok_or("top_k must be a number")?;
    let geo = doc.get("geomean_speedup").and_then(Json::as_f64).ok_or("geomean_speedup")?;
    let agree = doc.get("rank_agreement").and_then(Json::as_f64).ok_or("rank_agreement")?;
    let rows = doc.get("rows").and_then(Json::as_arr).ok_or("rows must be an array")?;
    if rows.is_empty() {
        return Err("rows must be non-empty".into());
    }

    let mut speedups = Vec::with_capacity(rows.len());
    let mut agrees = 0usize;
    let mut want_row: Vec<&str> = ROW_FIELDS.to_vec();
    want_row.sort_unstable();
    for (i, row) in rows.iter().enumerate() {
        let o = row.as_obj().ok_or_else(|| format!("row {i} must be an object"))?;
        let keys: Vec<&str> = o.keys().map(String::as_str).collect();
        if keys != want_row {
            return Err(format!("row {i} keys {keys:?} != schema {want_row:?}"));
        }
        for k in ["bench", "matrix", "family", "algo", "baseline"] {
            row.get(k)
                .and_then(Json::as_str)
                .ok_or_else(|| format!("row {i}: {k} must be a string"))?;
        }
        let num = |k: &str| -> Result<f64, String> {
            row.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("row {i}: {k} must be a number"))
        };
        let est = num("est_time_us")?;
        let base = num("baseline_time_us")?;
        let sp = num("speedup_vs_baseline")?;
        if !(est > 0.0 && base > 0.0 && sp > 0.0) {
            return Err(format!("row {i}: non-positive time/speedup"));
        }
        if num("gflops")? < 0.0 || num("width")? < 1.0 {
            return Err(format!("row {i}: bad gflops/width"));
        }
        let (grid, survivors) = (num("grid")?, num("survivors")?);
        if !(survivors >= 1.0 && grid >= survivors) {
            return Err(format!("row {i}: survivors {survivors} outside [1, grid={grid}]"));
        }
        // ratio consistency, with slack for the 4-decimal rounding
        let want_sp = base / est;
        if (sp - want_sp).abs() > 0.02 * want_sp + 0.01 {
            return Err(format!("row {i}: speedup {sp} != baseline/est {want_sp:.4}"));
        }
        match row.get("model_rank_agree") {
            Some(Json::Bool(b)) => {
                if *b {
                    agrees += 1;
                }
            }
            _ => return Err(format!("row {i}: model_rank_agree must be a bool")),
        }
        speedups.push(sp);
    }
    let want_geo = geomean(&speedups);
    if (geo - want_geo).abs() > 0.01 * want_geo + 0.01 {
        return Err(format!("geomean_speedup {geo} != {want_geo:.4} from rows"));
    }
    let want_agree = agrees as f64 / rows.len() as f64;
    if (agree - want_agree).abs() > 0.5 / rows.len() as f64 + 0.01 {
        return Err(format!("rank_agreement {agree} != {want_agree:.4} from rows"));
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// the suites `sgap bench` runs
// ---------------------------------------------------------------------------

/// The seeded COO-3 tensors of the tensor report: uniform, dense-row
/// (long segments) and sparse-row (short segments) regimes.
pub fn bench_tensor_suite() -> Vec<(&'static str, &'static str, Coo3)> {
    vec![
        ("coo3_uniform_128x96x64", "uniform", Coo3::random((128, 96, 64), 4000, 7)),
        ("coo3_dense_rows_64", "dense-rows", Coo3::random((64, 48, 32), 6000, 9)),
        ("coo3_sparse_rows_512", "sparse-rows", Coo3::random((512, 64, 32), 2000, 11)),
    ]
}

/// Cheapest candidate by the analytic model; ties break to the earliest
/// grid point (a strictly-less scan in grid order — the seeded-JSON
/// transliteration in `python/tools/seed_bench.py` mirrors this scan, so
/// keep the two in sync). `None` when nothing in `cands` prices the
/// workload.
fn cheapest<'a>(model: &CostModel, cands: &'a [Algo], wl: &Workload) -> Option<(&'a Algo, f64)> {
    let mut best: Option<(&'a Algo, f64)> = None;
    for alg in cands {
        let Some(t) = model.price(alg, wl) else { continue };
        if best.is_none_or(|(_, bt)| t < bt) {
            best = Some((alg, t));
        }
    }
    best
}

fn pruned_row(
    bench: &'static str,
    matrix: &str,
    family: &str,
    width: u32,
    pruned: &PrunedOutcome,
    baseline: &Algo,
    baseline_time_s: f64,
) -> Result<BenchRow> {
    let (best, t) = pruned.best().context("empty pruned sweep")?;
    let gflops = pruned.outcome.ranked[0].2;
    Ok(BenchRow {
        bench,
        matrix: matrix.to_string(),
        family: family.to_string(),
        width,
        algo: best.name(),
        baseline: baseline.name(),
        est_time_us: t * 1e6,
        baseline_time_us: baseline_time_s * 1e6,
        gflops,
        speedup_vs_baseline: baseline_time_s / t,
        model_rank_agree: pruned.model_rank_agree,
        grid: pruned.grid,
        survivors: pruned.survivors,
    })
}

/// Run the SpMM report: per suite matrix, the table-1/2 compiler-family
/// grid (TACO ∪ sgap, baseline = stock `{<1/32 row, c col>, 32}`) and the
/// table-4 dgSPARSE grid (baseline = stock `<32, 256, 32, rows>`), both
/// through the model-pruned tuner.
pub fn run_spmm_bench(machine: &Machine, quick: bool, top_k: usize) -> Result<BenchReport> {
    let n = 4u32;
    let suite = if quick { dataset::mini_suite() } else { bench_suite() };
    let mut rows = Vec::new();
    for d in &suite {
        let a = d.matrix.to_csr();
        let b = random_b(a.cols, n as usize, 17);

        let mut cands = tuner::taco_candidates(n);
        cands.extend(tuner::sgap_candidates(n));
        let pruned = tuner::tune_pruned(machine, &cands, &a, &b, n, top_k)?;
        let c_max = *c_values(n).last().unwrap_or(&1);
        let stock = Algo::SgapRowGroup { g: 32, c: c_max, r: 32 };
        let t_stock = stock.run(machine, &a, &b, n)?.time_s;
        rows.push(pruned_row("families", &d.name, d.family, n, &pruned, &stock, t_stock)?);

        let dg = tuner::space::dg_candidates_small(n);
        let pruned = tuner::tune_pruned(machine, &dg, &a, &b, n, top_k)?;
        let stock = Algo::Dg(DgConfig::stock(n));
        let t_stock = stock.run(machine, &a, &b, n)?.time_s;
        rows.push(pruned_row("dgsparse", &d.name, d.family, n, &pruned, &stock, t_stock)?);
    }

    // The skew table: the per-band hybrid's analytic cost vs the best
    // single catalog plan's, on the matrices where banding should pay.
    // Emitted in quick mode too — these are analytic prices (no warp
    // simulation), so the whole table costs three stats passes.
    let selector = Selector::default();
    let model = CostModel::new(machine);
    for d in &skew_suite() {
        let a = d.matrix.to_csr();
        let stats = MatrixStats::of(&a);
        let (composite, t_comp, single, t_single) = selector
            .banded_report(&model, &stats, n)
            .with_context(|| format!("{}: skew matrix declined banding", d.name))?;
        anyhow::ensure!(
            t_comp <= t_single,
            "{}: hybrid priced above best single plan ({t_comp:.3e} > {t_single:.3e})",
            d.name
        );
        let bands = match composite {
            Algo::Composite(cc) => cc.bands as usize,
            _ => unreachable!("banded_report returns a composite"),
        };
        rows.push(BenchRow {
            bench: "skew",
            matrix: d.name.clone(),
            family: d.family.to_string(),
            width: n,
            algo: composite.name(),
            baseline: single.name(),
            est_time_us: t_comp * 1e6,
            baseline_time_us: t_single * 1e6,
            gflops: 0.0,
            speedup_vs_baseline: t_single / t_comp,
            model_rank_agree: true,
            grid: tuner::band_candidates(n).len(),
            survivors: bands,
        });
    }
    anyhow::ensure!(
        rows.iter().any(|r| r.bench == "skew" && r.speedup_vs_baseline > 1.0),
        "no skew row where the hybrid strictly beats the best single plan"
    );

    // The fused table: the attention chain `C = (A ⊙ (X1·X2))·B` priced
    // as ONE kernel vs the best two-stage pipeline (best SDDMM plan +
    // best SpMM plan over the same grids the tuner sweeps), analytic
    // prices at the GNN-attention widths J = 32, N = 4. Self-enforcing
    // like the skew table: fusion shares the consumer's traversal
    // skeleton and drops the second pos/crd pass and the nnz-sized
    // intermediate, so it must never price above the pipeline it
    // replaces — and must beat it by >= 1.5x somewhere (the small-graph
    // footprint-amortization regime `fused_suite` carries).
    let j_fused = 32u32;
    let fused_cands = tuner::fused_candidates(j_fused, n);
    let sddmm_cands = tuner::sddmm_candidates(j_fused);
    let mut spmm_cands = tuner::taco_candidates(n);
    spmm_cands.extend(tuner::sgap_candidates(n));
    for d in &fused_suite() {
        let a = d.matrix.to_csr();
        let stats = MatrixStats::of(&a);
        let (fused_algo, t_fused) =
            cheapest(&model, &fused_cands, &Workload::Fused { stats: &stats, j: j_fused, n })
                .with_context(|| format!("{}: no fused plan for J={j_fused} N={n}", d.name))?;
        let (sddmm_algo, t_sddmm) =
            cheapest(&model, &sddmm_cands, &Workload::Sddmm { stats: &stats, j: j_fused })
                .with_context(|| format!("{}: no SDDMM plan for J={j_fused}", d.name))?;
        let (spmm_algo, t_spmm) =
            cheapest(&model, &spmm_cands, &Workload::Spmm { stats: &stats, n })
                .with_context(|| format!("{}: no SpMM plan for N={n}", d.name))?;
        let t_two = t_sddmm + t_spmm;
        anyhow::ensure!(
            t_fused <= t_two,
            "{}: fused kernel priced above the two-stage pipeline it replaces \
             ({t_fused:.3e} > {t_two:.3e})",
            d.name
        );
        rows.push(BenchRow {
            bench: "fused",
            matrix: d.name.clone(),
            family: d.family.to_string(),
            width: n,
            algo: fused_algo.name(),
            baseline: format!("{} + {}", sddmm_algo.name(), spmm_algo.name()),
            est_time_us: t_fused * 1e6,
            baseline_time_us: t_two * 1e6,
            gflops: 0.0,
            speedup_vs_baseline: t_two / t_fused,
            model_rank_agree: true,
            grid: fused_cands.len(),
            survivors: 1,
        });
    }
    anyhow::ensure!(
        rows.iter().any(|r| r.bench == "fused" && r.speedup_vs_baseline >= 1.5),
        "no fused row at >= 1.5x over the two-stage pipeline"
    );
    Ok(BenchReport {
        suite: "spmm",
        generator: format!("sgap bench{} (spmm, N={n})", if quick { " --quick" } else { "" }),
        hw: machine.hw.name.to_string(),
        quick,
        top_k,
        rows,
    })
}

/// Run the tensor report: MTTKRP and TTM over [`bench_tensor_suite`],
/// baseline = the stock-width `r = 32` segment kernel at maximal
/// coarsening — the "fixed group size" the paper tunes away from.
pub fn run_tensor_bench(machine: &Machine, quick: bool, top_k: usize) -> Result<BenchReport> {
    let width = 16u32;
    let c_max = *c_values(width).last().unwrap_or(&1);
    let mut rows = Vec::new();
    // all three regimes even in quick mode: the short-segment tensor is
    // the one the group-size headline keys on, and the tensors are small
    let tensors = bench_tensor_suite();
    for (name, family, t) in &tensors {
        let mut rng = SplitMix64::new(23);
        let x1: Vec<f32> = (0..t.dim1 * width as usize).map(|_| rng.value()).collect();
        let x2: Vec<f32> = (0..t.dim2 * width as usize).map(|_| rng.value()).collect();
        let cands = tuner::mttkrp_candidates(width);
        anyhow::ensure!(!cands.is_empty(), "no MTTKRP candidates for J={width}");
        let pruned = tuner::tune_mttkrp_pruned(machine, &cands, t, &x1, &x2, top_k)?;
        let stock = Algo::Mttkrp(MttkrpConfig::new(width, c_max, 32));
        let t_stock = stock.run_mttkrp(machine, t, &x1, &x2)?.time_s;
        rows.push(pruned_row("mttkrp", name, family, width, &pruned, &stock, t_stock)?);

        let lx1: Vec<f32> = (0..t.dim2 * width as usize).map(|_| rng.value()).collect();
        let cands = tuner::ttm_candidates(width);
        anyhow::ensure!(!cands.is_empty(), "no TTM candidates for L={width}");
        let pruned = tuner::tune_ttm_pruned(machine, &cands, t, &lx1, top_k)?;
        let stock = Algo::Ttm(TtmConfig::new(width, c_max, 32));
        let t_stock = stock.run_ttm(machine, t, &lx1)?.time_s;
        rows.push(pruned_row("ttm", name, family, width, &pruned, &stock, t_stock)?);
    }
    Ok(BenchReport {
        suite: "tensor",
        generator: format!(
            "sgap bench{} (tensor, J=L={width})",
            if quick { " --quick" } else { "" }
        ),
        hw: machine.hw.name.to_string(),
        quick,
        top_k,
        rows,
    })
}

// ---------------------------------------------------------------------------
// offline profiling (`sgap profile` → CALIBRATION.json)
// ---------------------------------------------------------------------------

/// Rank fidelity on one profiled matrix: Spearman correlation between the
/// analytic model's candidate ranking and the simulator's, before and
/// after the fit.
#[derive(Debug, Clone)]
pub struct ProfileRow {
    pub matrix: String,
    /// Candidates both priced and measured on this matrix.
    pub samples: usize,
    pub spearman_before: f64,
    pub spearman_after: f64,
}

/// What `sgap profile` produces: the fitted [`Calibration`] plus the
/// per-matrix before/after rank fidelity it was judged on.
#[derive(Debug, Clone)]
pub struct ProfileReport {
    pub calibration: Calibration,
    pub rows: Vec<ProfileRow>,
    pub quick: bool,
}

impl ProfileReport {
    pub fn mean_spearman_before(&self) -> f64 {
        mean(self.rows.iter().map(|r| r.spearman_before))
    }

    pub fn mean_spearman_after(&self) -> f64 {
        mean(self.rows.iter().map(|r| r.spearman_after))
    }
}

fn mean(xs: impl Iterator<Item = f64>) -> f64 {
    let v: Vec<f64> = xs.collect();
    if v.is_empty() {
        return 0.0;
    }
    v.iter().sum::<f64>() / v.len() as f64
}

/// The offline profile→fit pipeline behind `sgap profile`: sweep the
/// SpMM candidate grid over the bench suite on the warp simulator (the
/// stand-in for hardware timers), fit `CostParams` +
/// `launch_overhead_s` to the measurements, and report how the analytic
/// model's candidate ranking correlates with the simulator's before vs
/// after the fit. The returned calibration is what `sgap serve --calib`
/// warm-starts from.
pub fn run_profile(machine: &Machine, quick: bool) -> Result<ProfileReport> {
    let n = 4u32;
    let suite = if quick { dataset::mini_suite() } else { bench_suite() };
    let mut cands = tuner::taco_candidates(n);
    cands.extend(tuner::sgap_candidates(n));

    // measure every candidate once per matrix; the same sweep feeds the
    // fitter (as samples) and the fidelity report (as ground-truth ranks)
    let mut measured: Vec<(String, MatrixStats, Vec<(crate::algos::catalog::Algo, f64)>)> =
        Vec::new();
    let mut samples = Vec::new();
    for d in &suite {
        let a = d.matrix.to_csr();
        let b = random_b(a.cols, n as usize, 17);
        let out = tuner::tune(machine, &cands, &a, &b, n)?;
        let stats = MatrixStats::of(&a);
        for (alg, t, _) in &out.ranked {
            samples.push(Sample::new(*alg, WorkloadSpec::Spmm { stats: stats.clone(), n }, *t));
        }
        let times = out.ranked.iter().map(|(a, t, _)| (*a, *t)).collect();
        measured.push((d.name.clone(), stats, times));
    }

    let calibration = calibrate::fit(machine, &samples);

    let before = CostModel::new(machine);
    let mut fitted_machine = machine.clone();
    calibration.apply(&mut fitted_machine);
    let after = CostModel::new(&fitted_machine);
    let mut rows = Vec::new();
    for (name, stats, times) in &measured {
        let wl = Workload::Spmm { stats, n };
        let (mut pb, mut pa, mut ms) = (Vec::new(), Vec::new(), Vec::new());
        for (alg, t) in times {
            let (Some(b), Some(f)) = (before.price(alg, &wl), after.price(alg, &wl)) else {
                continue;
            };
            pb.push(b);
            pa.push(f);
            ms.push(*t);
        }
        rows.push(ProfileRow {
            matrix: name.clone(),
            samples: ms.len(),
            spearman_before: calibrate::spearman(&pb, &ms),
            spearman_after: calibrate::spearman(&pa, &ms),
        });
    }
    Ok(ProfileReport { calibration, rows, quick })
}

/// Validate a `CALIBRATION.json` document: exact key sets, version, and
/// the fit invariants (positive params, non-negative overhead, fitted
/// loss no worse than the starting loss, at least one sample). The drift
/// gate for the committed artifact, mirroring [`validate_bench_json`].
pub fn validate_calibration_json(src: &str) -> Result<(), String> {
    let doc = Json::parse(src).map_err(|e| e.to_string())?;
    let obj = doc.as_obj().ok_or("top level must be an object")?;
    let keys: Vec<&str> = obj.keys().map(String::as_str).collect();
    let mut want = vec![
        "schema_version",
        "hw",
        "samples",
        "loss_before",
        "loss_after",
        "launch_overhead_s",
        "params",
    ];
    want.sort_unstable();
    if keys != want {
        return Err(format!("top-level keys {keys:?} != schema {want:?}"));
    }
    let ver = doc.get("schema_version").and_then(Json::as_f64).ok_or("schema_version")?;
    if ver as u64 != calibrate::CALIBRATION_SCHEMA_VERSION {
        return Err(format!(
            "schema_version {ver} != {}",
            calibrate::CALIBRATION_SCHEMA_VERSION
        ));
    }
    doc.get("hw").and_then(Json::as_str).ok_or("hw must be a string")?;
    let samples = doc.get("samples").and_then(Json::as_f64).ok_or("samples")?;
    if samples < 1.0 {
        return Err("a committed calibration must have fitted >= 1 sample".into());
    }
    let lb = doc.get("loss_before").and_then(Json::as_f64).ok_or("loss_before")?;
    let la = doc.get("loss_after").and_then(Json::as_f64).ok_or("loss_after")?;
    if !(lb.is_finite() && la.is_finite() && lb >= 0.0 && la >= 0.0) {
        return Err(format!("losses must be finite and non-negative ({lb}, {la})"));
    }
    if la > lb + 1e-12 {
        return Err(format!("loss_after {la} worse than loss_before {lb}"));
    }
    let overhead =
        doc.get("launch_overhead_s").and_then(Json::as_f64).ok_or("launch_overhead_s")?;
    if !(overhead.is_finite() && overhead >= 0.0) {
        return Err(format!("launch_overhead_s must be >= 0 ({overhead})"));
    }
    let params = doc.get("params").ok_or("params")?;
    let pobj = params.as_obj().ok_or("params must be an object")?;
    let pkeys: Vec<&str> = pobj.keys().map(String::as_str).collect();
    let mut pwant: Vec<&str> = crate::sim::CostParams::NAMES.to_vec();
    pwant.sort_unstable();
    if pkeys != pwant {
        return Err(format!("param keys {pkeys:?} != schema {pwant:?}"));
    }
    for name in crate::sim::CostParams::NAMES {
        let v = params.get(name).and_then(Json::as_f64).ok_or(name)?;
        if !(v.is_finite() && v > 0.0) {
            return Err(format!("param {name} must be a positive number ({v})"));
        }
    }
    Ok(())
}

/// Validate a `PLANS.json` plan-catalog document: exact key sets per
/// level, version, scenario/origin vocabulary, and positive widths. The
/// drift gate for the committed artifact, mirroring
/// [`validate_calibration_json`]. Structural depth — per-family algo
/// fields, hybrid band plans — is delegated to the typed parser, which
/// rejects anything it cannot round-trip.
pub fn validate_plan_catalog_json(src: &str) -> Result<(), String> {
    use crate::coordinator::{OpKind, PlanCatalog, PLAN_CATALOG_SCHEMA_VERSION};
    // the typed parser enforces per-family field presence and band
    // structure; run it first so its errors name the offending entry
    PlanCatalog::from_json(src).map_err(|e| format!("{e:#}"))?;
    let doc = Json::parse(src).map_err(|e| e.to_string())?;
    let obj = doc.as_obj().ok_or("top level must be an object")?;
    let keys: Vec<&str> = obj.keys().map(String::as_str).collect();
    let mut want = vec!["schema_version", "entries"];
    want.sort_unstable();
    if keys != want {
        return Err(format!("top-level keys {keys:?} != schema {want:?}"));
    }
    let ver = doc.get("schema_version").and_then(Json::as_f64).ok_or("schema_version")?;
    if ver as u64 != PLAN_CATALOG_SCHEMA_VERSION {
        return Err(format!("schema_version {ver} != {PLAN_CATALOG_SCHEMA_VERSION}"));
    }
    let entries = doc.get("entries").and_then(Json::as_arr).ok_or("entries must be an array")?;
    for (i, entry) in entries.iter().enumerate() {
        let eobj = entry.as_obj().ok_or(format!("entry {i} must be an object"))?;
        let ekeys: Vec<&str> = eobj.keys().map(String::as_str).collect();
        let mut ewant = vec![
            "scenario", "rows", "cols", "nnz", "width", "cv_q", "mean_q", "empty_q", "origin",
            "plan",
        ];
        ewant.sort_unstable();
        if ekeys != ewant {
            return Err(format!("entry {i} keys {ekeys:?} != schema {ewant:?}"));
        }
        let scenario = entry.get("scenario").and_then(Json::as_str).ok_or("scenario")?;
        if OpKind::from_label(scenario).is_none() {
            return Err(format!("entry {i}: unknown scenario {scenario:?}"));
        }
        let origin = entry.get("origin").and_then(Json::as_str).ok_or("origin")?;
        if !matches!(origin, "selector" | "tuned") {
            return Err(format!("entry {i}: unknown origin {origin:?}"));
        }
        for field in ["rows", "cols", "nnz", "width", "cv_q", "mean_q", "empty_q"] {
            let v = entry.get(field).and_then(Json::as_f64).ok_or(field)?;
            if !(v.is_finite() && v >= 0.0 && v.fract() == 0.0) {
                return Err(format!("entry {i}: {field} must be a non-negative integer ({v})"));
            }
        }
        let width = entry.get("width").and_then(Json::as_f64).unwrap_or(0.0);
        if width < 1.0 {
            return Err(format!("entry {i}: width must be positive ({width})"));
        }
        entry
            .get("plan")
            .and_then(|p| p.get("algo"))
            .and_then(Json::as_str)
            .ok_or(format!("entry {i}: plan.algo must be a string"))?;
    }
    Ok(())
}

/// Fixed-width table printer.
pub struct Table {
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let parts: Vec<String> =
                cells.iter().enumerate().map(|(i, c)| format!("{:<w$}", c, w = widths[i])).collect();
            println!("| {} |", parts.join(" | "));
        };
        line(&self.headers);
        println!(
            "|{}|",
            widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("|")
        );
        for row in &self.rows {
            line(row);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_of_constant() {
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn normalized_clamps_at_one() {
        assert_eq!(normalized_speedup(2.0, 1.0), 1.0); // A slower: count 1
        assert_eq!(normalized_speedup(1.0, 2.0), 2.0);
    }

    #[test]
    fn plan_catalog_validator_gates_the_committed_schema() {
        use crate::algos::catalog::Algo;
        use crate::coordinator::catalog::CatalogEntry;
        use crate::coordinator::{
            OpKind, Plan, PlanCatalog, PlanOrigin, ShapeKey, PLAN_CATALOG_SCHEMA_VERSION,
        };
        let key = ShapeKey::from_parts(OpKind::Spmm, 64, 48, 400, 4, 8, 2, 1);
        let plan = Plan { kind: Algo::SgapNnzGroup { c: 4, r: 32 }, origin: PlanOrigin::Tuned };
        let cat = PlanCatalog {
            version: PLAN_CATALOG_SCHEMA_VERSION,
            entries: vec![CatalogEntry { key, plan }],
        };
        let json = cat.to_json();
        validate_plan_catalog_json(&json).unwrap();
        // version drift
        let bad = json.replace("\"schema_version\": 1", "\"schema_version\": 9");
        assert!(validate_plan_catalog_json(&bad).is_err());
        // vocabulary drift
        let bad = json.replace("\"origin\": \"tuned\"", "\"origin\": \"oracle\"");
        assert!(validate_plan_catalog_json(&bad).is_err());
        // a lost key fails the exact-key-set gate
        let bad = json.replace("      \"width\": 4,\n", "");
        assert!(validate_plan_catalog_json(&bad).is_err());
        // an extra key fails too — the typed parser tolerates it (get()
        // by name), so only this validator pins the byte schema
        let bad = json.replace("      \"rows\": 64,\n", "      \"rank\": 2,\n      \"rows\": 64,\n");
        assert!(validate_plan_catalog_json(&bad).is_err());
    }

    #[test]
    fn skew_suite_is_the_fixed_trio() {
        let names: Vec<String> = skew_suite().iter().map(|d| d.name.clone()).collect();
        assert_eq!(names, ["pl_2048_a1.6", "pl_4096_a2", "block_2048_b16"]);
    }

    #[test]
    fn fused_suite_is_the_fixed_trio() {
        let suite = fused_suite();
        let names: Vec<String> = suite.iter().map(|d| d.name.clone()).collect();
        assert_eq!(names, ["er_2048_d2e-3", "band_2048_w9", "er_128_d2e-1"]);
        // the committed-coverage test counts mini-suite names exactly
        // twice in BENCH_spmm.json — the fused rows must not collide
        for d in &suite {
            assert!(
                !dataset::mini_suite().iter().any(|m| m.name == d.name),
                "{} shadows a mini-suite matrix",
                d.name
            );
        }
        let small = &suite[2];
        assert_eq!(
            (small.matrix.rows, small.matrix.cols, small.matrix.vals.len()),
            (128, 128, 3276)
        );
    }

    #[test]
    fn bench_suite_spans_families() {
        let s = bench_suite();
        let fams: std::collections::HashSet<&str> = s.iter().map(|d| d.family).collect();
        assert!(fams.len() >= 4, "families: {fams:?}");
    }

    fn sample_calibration() -> Calibration {
        let machine = Machine::new(crate::sim::HwProfile::rtx3090());
        let mut c = Calibration::identity(&machine);
        c.samples = 3;
        c.loss_before = 0.5;
        c.loss_after = 0.25;
        c
    }

    #[test]
    fn calibration_validator_accepts_a_fit_artifact() {
        validate_calibration_json(&sample_calibration().to_json()).unwrap();
    }

    #[test]
    fn calibration_validator_rejects_drift() {
        // unfitted artifact (zero samples)
        let machine = Machine::new(crate::sim::HwProfile::rtx3090());
        let identity = Calibration::identity(&machine);
        assert!(validate_calibration_json(&identity.to_json()).is_err());
        // a fit that made the loss worse
        let mut worse = sample_calibration();
        worse.loss_after = worse.loss_before * 2.0;
        assert!(validate_calibration_json(&worse.to_json()).is_err());
        // schema-version drift
        let mut old = sample_calibration();
        old.version = 999;
        assert!(validate_calibration_json(&old.to_json()).is_err());
        // a param driven to zero
        let mut zeroed = sample_calibration();
        zeroed.params.alu = 0.0;
        assert!(validate_calibration_json(&zeroed.to_json()).is_err());
        // a dropped key
        let src = sample_calibration().to_json().replace("  \"hw\": \"RTX 3090\",\n", "");
        assert!(validate_calibration_json(&src).is_err());
    }
}
