//! End-to-end driver (DESIGN.md §6): all three layers composing on a real
//! small workload.
//!
//! Workload: 2-layer GCN inference on a Cora-scale synthetic graph
//! (2708 nodes, ~13k edges, 64 features). The aggregation inside the HLO
//! artifact is the paper's segment-group SpMM written in Pallas (L1),
//! lowered by jax (L2), executed from rust via PJRT (L3) — Python never
//! runs here.
//!
//! Reports: numeric check vs the rust oracle, per-inference latency and
//! throughput through the coordinator, and the simulator's kernel-time
//! estimate for the selected SpMM algorithm on the paper's three GPUs.
//!
//! Run: `make artifacts && cargo run --release --example e2e_gcn`

use std::time::Instant;

use sgap::algos::catalog::Algo;
use sgap::algos::cpu_ref::{max_rel_err, spmm_serial};
use sgap::coordinator::{Coordinator, CoordinatorConfig, Request};
use sgap::runtime::Runtime;
use sgap::sim::{HwProfile, Machine};
use sgap::sparse::{erdos_renyi, gen, MatrixStats, SplitMix64};
use sgap::tuner::Selector;

fn main() -> anyhow::Result<()> {
    let dir = Runtime::default_dir();
    anyhow::ensure!(
        dir.join("manifest.json").exists(),
        "no artifacts at {} — run `make artifacts` first",
        dir.display()
    );

    // ---- the graph (Cora-scale) ----------------------------------------
    let nodes = 2708usize;
    let edges = 10_000usize;
    let graph = gen::normalize_adjacency(&erdos_renyi(nodes, nodes, edges, 1));
    let a = graph.to_csr();
    let stats = MatrixStats::of(&a);
    println!(
        "graph: {} nodes, {} edges (w/ self loops), density {:.2e}, degree cv {:.2}",
        nodes,
        a.nnz(),
        stats.density,
        stats.row_degree_cv
    );

    let mut rt = Runtime::load(&dir)?;
    println!("pjrt platform: {}", rt.platform());
    let spec = rt.registry.get("gcn2")?.clone();
    let (fi, hd, fo) = (spec.in_feat, spec.hidden, spec.out_feat);

    let mut rng = SplitMix64::new(2);
    let h: Vec<f32> = (0..nodes * fi).map(|_| rng.value()).collect();
    let w1: Vec<f32> = (0..fi * hd).map(|_| rng.value() * 0.1).collect();
    let w2: Vec<f32> = (0..hd * fo).map(|_| rng.value() * 0.1).collect();

    // ---- numeric check: PJRT artifact vs rust oracle --------------------
    let t0 = Instant::now();
    let got = rt.run_gcn2("gcn2", &a, &h, &w1, &w2)?;
    let compile_and_first = t0.elapsed();

    let want = {
        let matmul = |x: &[f32], y: &[f32], m: usize, k: usize, n: usize| {
            let mut out = vec![0f32; m * n];
            for i in 0..m {
                for kk in 0..k {
                    let xv = x[i * k + kk];
                    for j in 0..n {
                        out[i * n + j] += xv * y[kk * n + j];
                    }
                }
            }
            out
        };
        let relu = |v: &mut Vec<f32>| v.iter_mut().for_each(|x| *x = x.max(0.0));
        let mut z1 = spmm_serial(&a, &matmul(&h, &w1, nodes, fi, hd), hd);
        relu(&mut z1);
        let mut z2 = spmm_serial(&a, &matmul(&z1, &w2, nodes, hd, fo), fo);
        relu(&mut z2);
        z2
    };
    let err = max_rel_err(&got, &want);
    println!("gcn2 numeric check: max rel err {err:.2e} (compile+first run {compile_and_first:?})");
    anyhow::ensure!(err < 5e-4, "numerics diverged");

    // ---- inference latency (executable hot) -----------------------------
    let iters = 20;
    let t0 = Instant::now();
    for _ in 0..iters {
        let _ = rt.run_gcn2("gcn2", &a, &h, &w1, &w2)?;
    }
    let per_inf = t0.elapsed().as_secs_f64() / iters as f64;
    println!(
        "gcn2 inference: {:.2} ms/graph ({:.1} graphs/s, {} nodes each)",
        per_inf * 1e3,
        1.0 / per_inf,
        nodes
    );

    // ---- batched SpMM serving through the coordinator -------------------
    let coord = Coordinator::start(CoordinatorConfig {
        artifacts_dir: Some(dir),
        ..CoordinatorConfig::default()
    })?;
    let reqs = 64;
    let t0 = Instant::now();
    let mut rxs = Vec::new();
    for i in 0..reqs {
        let m = erdos_renyi(500, 500, 3000, 100 + i as u64).to_csr();
        let b: Vec<f32> = (0..500 * 4).map(|_| rng.value()).collect();
        rxs.push(coord.submit(Request::Spmm { a: m, b, n: 4 }));
    }
    let mut pjrt_served = 0;
    for rx in rxs {
        let resp = rx.recv().unwrap().map_err(|e| anyhow::anyhow!(e))?;
        if resp.backend.is_pjrt() {
            pjrt_served += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let snap = coord.metrics.snapshot();
    println!(
        "coordinator: {reqs} SpMM requests in {:.1} ms ({:.0} req/s, {} batches, {}/{} on PJRT, p50 {} us, p99 {} us)",
        wall * 1e3,
        reqs as f64 / wall,
        snap.batches,
        pjrt_served,
        reqs,
        snap.p50_us,
        snap.p99_us
    );
    coord.shutdown();

    // ---- simulator estimate for the selected kernel ---------------------
    let sel = Selector::default();
    let algo = sel.select(&stats, 4);
    println!("\nselector picks {} for this graph; simulated SpMM kernel time:", algo.name());
    let b4: Vec<f32> = (0..nodes * 4).map(|_| rng.value()).collect();
    for hw in HwProfile::all() {
        let machine = Machine::new(hw);
        let res = algo.run(&machine, &a, &b4, 4)?;
        println!(
            "  {:<11} {:>8.2} us ({}-bound, {:.1} GFLOP/s)",
            hw.name,
            res.time_s * 1e6,
            res.run.report.bound,
            res.gflops
        );
    }
    // cross-check: the simulated kernel numerics agree with PJRT numerics
    let sim_res = algo.run(&Machine::new(HwProfile::rtx3090()), &a, &b4, 4)?;
    let pjrt_c = rt.run_spmm_nnz(
        rt.registry
            .route(sgap::runtime::ArtifactKind::SpmmNnzSr, nodes, nodes, a.nnz())
            .map(|s| s.name.clone())
            .unwrap_or_else(|| "gcn-bucket-too-small".into())
            .as_str(),
        &a,
        &b4,
    );
    match pjrt_c {
        Ok(c) => {
            let err = max_rel_err(&sim_res.run.c, &c);
            println!("simulator vs PJRT numerics: max rel err {err:.2e}");
            anyhow::ensure!(err < 5e-4);
        }
        Err(e) => println!("(PJRT cross-check skipped: {e})"),
    }

    println!("\ne2e_gcn OK");
    Ok(())
}
