//! End-to-end driver (DESIGN.md §6): all three layers composing on a real
//! small workload.
//!
//! Workload: 2-layer GCN inference on a Cora-scale synthetic graph
//! (2708 nodes, ~13k edges, 64 features). The aggregation inside the HLO
//! artifact is the paper's segment-group SpMM written in Pallas (L1),
//! lowered by jax (L2), executed from rust via PJRT (L3) — Python never
//! runs here.
//!
//! Reports: numeric check vs the rust oracle, per-inference latency and
//! throughput through the coordinator, a graph-attention stage served as
//! **one fused SDDMM→SpMM submit** (with the fused-vs-two-stage simulated
//! kernel time), and the simulator's kernel-time estimate for the
//! selected SpMM algorithm on the paper's three GPUs.
//!
//! Run: `make artifacts && cargo run --release --example e2e_gcn`

use std::time::Instant;

use anyhow::Context;

use sgap::algos::catalog::Algo;
use sgap::algos::cpu_ref::{max_rel_err, spmm_serial};
use sgap::algos::fused::fused_serial;
use sgap::algos::sddmm::sddmm_serial;
use sgap::coordinator::{Coordinator, CoordinatorConfig, Request, Session};
use sgap::runtime::Runtime;
use sgap::sim::{HwProfile, Machine};
use sgap::sparse::{erdos_renyi, gen, Csr, MatrixStats, SplitMix64};
use sgap::tuner::{CostModel, Selector};

fn main() -> anyhow::Result<()> {
    let dir = Runtime::default_dir();
    anyhow::ensure!(
        dir.join("manifest.json").exists(),
        "no artifacts at {} — run `make artifacts` first",
        dir.display()
    );

    // ---- the graph (Cora-scale) ----------------------------------------
    let nodes = 2708usize;
    let edges = 10_000usize;
    let graph = gen::normalize_adjacency(&erdos_renyi(nodes, nodes, edges, 1));
    let a = graph.to_csr();
    let stats = MatrixStats::of(&a);
    println!(
        "graph: {} nodes, {} edges (w/ self loops), density {:.2e}, degree cv {:.2}",
        nodes,
        a.nnz(),
        stats.density,
        stats.row_degree_cv
    );

    let mut rt = Runtime::load(&dir)?;
    println!("pjrt platform: {}", rt.platform());
    let spec = rt.registry.get("gcn2")?.clone();
    let (fi, hd, fo) = (spec.in_feat, spec.hidden, spec.out_feat);

    let mut rng = SplitMix64::new(2);
    let h: Vec<f32> = (0..nodes * fi).map(|_| rng.value()).collect();
    let w1: Vec<f32> = (0..fi * hd).map(|_| rng.value() * 0.1).collect();
    let w2: Vec<f32> = (0..hd * fo).map(|_| rng.value() * 0.1).collect();

    // ---- numeric check: PJRT artifact vs rust oracle --------------------
    let t0 = Instant::now();
    let got = rt.run_gcn2("gcn2", &a, &h, &w1, &w2)?;
    let compile_and_first = t0.elapsed();

    let want = {
        let matmul = |x: &[f32], y: &[f32], m: usize, k: usize, n: usize| {
            let mut out = vec![0f32; m * n];
            for i in 0..m {
                for kk in 0..k {
                    let xv = x[i * k + kk];
                    for j in 0..n {
                        out[i * n + j] += xv * y[kk * n + j];
                    }
                }
            }
            out
        };
        let relu = |v: &mut Vec<f32>| v.iter_mut().for_each(|x| *x = x.max(0.0));
        let mut z1 = spmm_serial(&a, &matmul(&h, &w1, nodes, fi, hd), hd);
        relu(&mut z1);
        let mut z2 = spmm_serial(&a, &matmul(&z1, &w2, nodes, hd, fo), fo);
        relu(&mut z2);
        z2
    };
    let err = max_rel_err(&got, &want);
    println!("gcn2 numeric check: max rel err {err:.2e} (compile+first run {compile_and_first:?})");
    anyhow::ensure!(err < 5e-4, "numerics diverged");

    // ---- inference latency (executable hot) -----------------------------
    let iters = 20;
    let t0 = Instant::now();
    for _ in 0..iters {
        let _ = rt.run_gcn2("gcn2", &a, &h, &w1, &w2)?;
    }
    let per_inf = t0.elapsed().as_secs_f64() / iters as f64;
    println!(
        "gcn2 inference: {:.2} ms/graph ({:.1} graphs/s, {} nodes each)",
        per_inf * 1e3,
        1.0 / per_inf,
        nodes
    );

    // ---- batched SpMM serving through the coordinator -------------------
    let coord = Coordinator::start(CoordinatorConfig {
        artifacts_dir: Some(dir),
        ..CoordinatorConfig::default()
    })?;
    let reqs = 64;
    let t0 = Instant::now();
    let mut rxs = Vec::new();
    for i in 0..reqs {
        let m = erdos_renyi(500, 500, 3000, 100 + i as u64).to_csr();
        let b: Vec<f32> = (0..500 * 4).map(|_| rng.value()).collect();
        rxs.push(coord.submit(Request::Spmm { a: m, b, n: 4 }));
    }
    let mut pjrt_served = 0;
    for rx in rxs {
        let resp = rx.recv().unwrap().map_err(|e| anyhow::anyhow!(e))?;
        if resp.backend.is_pjrt() {
            pjrt_served += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let snap = coord.metrics.snapshot();
    println!(
        "coordinator: {reqs} SpMM requests in {:.1} ms ({:.0} req/s, {} batches, {}/{} on PJRT, p50 {} us, p99 {} us)",
        wall * 1e3,
        reqs as f64 / wall,
        snap.batches,
        pjrt_served,
        reqs,
        snap.p50_us,
        snap.p99_us
    );
    coord.shutdown();

    // ---- graph attention: one fused SDDMM→SpMM submit -------------------
    // Attention scores live only on the graph's sparsity — the classic
    // SDDMM→SpMM chain. Served as ONE submit: the fused kernel computes
    // each score in-register and consumes it immediately, so no nnz-sized
    // intermediate is ever materialized.
    let (j_att, n_att) = (32usize, 16usize);
    let q: Vec<f32> = (0..nodes * j_att).map(|_| rng.value() * 0.1).collect();
    let kt: Vec<f32> = (0..j_att * nodes).map(|_| rng.value() * 0.1).collect();
    let v: Vec<f32> = (0..nodes * n_att).map(|_| rng.value() * 0.1).collect();
    let session = Session::start(CoordinatorConfig::default())?;
    let ah = session.register_matrix(a.clone());
    let (qh, kh, vh) = (
        session.register_dense(q.clone()),
        session.register_dense(kt.clone()),
        session.register_dense(v.clone()),
    );
    let att = session.fused_sddmm_spmm(&ah, &qh, &kh, &vh, j_att, n_att).wait()?;
    let att_err = max_rel_err(&att.c, &fused_serial(&a, &q, &kt, &v, j_att, n_att));
    println!(
        "\nattention (one fused submit): backend {}, plan {}, max rel err {att_err:.2e}",
        att.backend,
        att.plan_label().unwrap_or_else(|| "-".into()),
    );
    anyhow::ensure!(att_err < 5e-4, "fused attention numerics diverged");
    session.shutdown();

    // Fused vs two-stage simulated kernel time on the same operands: the
    // two-stage pipeline materializes the nnz-sized score matrix and pays
    // a second launch + a second pos/crd traversal.
    let machine = Machine::new(HwProfile::rtx3090());
    let model = CostModel::new(&machine);
    let selector = Selector::default();
    let fused_plan = selector
        .select_fused_model(&model, &stats, j_att as u32, n_att as u32)
        .context("no legal fused launch shape for the attention widths")?;
    let t_fused = fused_plan.run_fused(&machine, &a, &q, &kt, &v)?.time_s;
    let sddmm_plan = selector.select_sddmm_model(&model, &stats, j_att as u32);
    let t_sddmm = sddmm_plan.run_sddmm(&machine, &a, &q, &kt)?.time_s;
    let scored = Csr { data: sddmm_serial(&a, &q, &kt, j_att), ..a.clone() };
    let spmm_plan = selector.select_model(&model, &stats, n_att as u32);
    let t_spmm = spmm_plan.run(&machine, &scored, &v, n_att as u32)?.time_s;
    println!(
        "attention kernel time (rtx3090 sim): fused {} {:.2} us vs two-stage {:.2} us \
         ({} {:.2} + {} {:.2}) — {:.2}x",
        fused_plan.name(),
        t_fused * 1e6,
        (t_sddmm + t_spmm) * 1e6,
        sddmm_plan.name(),
        t_sddmm * 1e6,
        spmm_plan.name(),
        t_spmm * 1e6,
        (t_sddmm + t_spmm) / t_fused
    );

    // ---- simulator estimate for the selected kernel ---------------------
    let sel = Selector::default();
    let algo = sel.select(&stats, 4);
    println!("\nselector picks {} for this graph; simulated SpMM kernel time:", algo.name());
    let b4: Vec<f32> = (0..nodes * 4).map(|_| rng.value()).collect();
    for hw in HwProfile::all() {
        let machine = Machine::new(hw);
        let res = algo.run(&machine, &a, &b4, 4)?;
        println!(
            "  {:<11} {:>8.2} us ({}-bound, {:.1} GFLOP/s)",
            hw.name,
            res.time_s * 1e6,
            res.run.report.bound,
            res.gflops
        );
    }
    // cross-check: the simulated kernel numerics agree with PJRT numerics
    let sim_res = algo.run(&Machine::new(HwProfile::rtx3090()), &a, &b4, 4)?;
    let pjrt_c = rt.run_spmm_nnz(
        rt.registry
            .route(sgap::runtime::ArtifactKind::SpmmNnzSr, nodes, nodes, a.nnz())
            .map(|s| s.name.clone())
            .unwrap_or_else(|| "gcn-bucket-too-small".into())
            .as_str(),
        &a,
        &b4,
    );
    match pjrt_c {
        Ok(c) => {
            let err = max_rel_err(&sim_res.run.c, &c);
            println!("simulator vs PJRT numerics: max rel err {err:.2e}");
            anyhow::ensure!(err < 5e-4);
        }
        Err(e) => println!("(PJRT cross-check skipped: {e})"),
    }

    println!("\ne2e_gcn OK");
    Ok(())
}
