//! **Fig. 11** — per-dataset series: best new-algorithm speedup over the
//! best original-TACO algorithm, against matrix density, for several N.
//!
//! Writes `results/fig11.csv` with columns
//! `hw,n,dataset,family,density,cv,t_taco_us,t_new_us,speedup` — the
//! series the paper plots (speedup vs density, one panel per N).
//!
//! Run: `cargo run --release --example fig11_sweep` (full suite; minutes)

use std::io::Write;

use sgap::bench_util::{normalized_speedup, random_b};
use sgap::sim::{HwProfile, Machine};
use sgap::sparse::{dataset, MatrixStats};
use sgap::tuner::{self, tune};

fn main() -> anyhow::Result<()> {
    std::fs::create_dir_all("results")?;
    let path = "results/fig11.csv";
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "hw,n,dataset,family,density,cv,t_taco_us,t_new_us,speedup")?;

    let suite = dataset::suite();
    let machine = Machine::new(HwProfile::rtx3090());
    for n in [4u32, 16] {
        let taco = tuner::space::taco_candidates(n);
        let sgap_c = tuner::space::sgap_candidates(n);
        println!("N = {n}: {} taco + {} sgap candidates over {} matrices", taco.len(), sgap_c.len(), suite.len());
        for d in &suite {
            let a = d.matrix.to_csr();
            let s = MatrixStats::of(&a);
            let b = random_b(a.cols, n as usize, 61);
            let t_taco = tune(&machine, &taco, &a, &b, n)?.best().expect("taco sweep").1;
            let t_new = tune(&machine, &sgap_c, &a, &b, n)?.best().expect("sgap sweep").1;
            let sp = normalized_speedup(t_new, t_taco);
            writeln!(
                f,
                "{},{},{},{},{:.3e},{:.3},{:.3},{:.3},{:.4}",
                machine.hw.name,
                n,
                d.name,
                d.family,
                s.density,
                s.row_degree_cv,
                t_taco * 1e6,
                t_new * 1e6,
                sp
            )?;
            println!("  {:<26} density {:>9.2e}  speedup {:.3}", d.name, s.density, sp);
        }
    }
    println!("\nwrote {path}");
    Ok(())
}
