//! Codegen demo: reproduce the paper's Listing 1 → Listing 2
//! transformation — the same schedule lowered without and with segment
//! group — plus the §5.3 macro-instruction header.
//!
//! Run: `cargo run --release --example codegen_demo`

use sgap::compiler::codegen_cuda::{emit_kernel, macro_header};
use sgap::compiler::schedule::{Schedule, SpmmConfig};

fn main() -> anyhow::Result<()> {
    let cfg = SpmmConfig { n: 4, c: 4, p: 256, g: 1, r: 32, x: 1 };

    println!("==== Listing 1 analogue: original TACO (serial reduction + atomicAdd) ====\n");
    let orig = Schedule::taco_nnz_serial(SpmmConfig { g: 1, ..cfg });
    println!("// CIN: {}\n", orig.to_cin());
    println!("{}", emit_kernel(&sgap::compiler::lower(&orig)?));

    println!("==== Listing 2 analogue: segment group (zero extension + segReduceGroup) ====\n");
    let seg = Schedule::sgap_nnz_group(cfg, 32);
    println!("// CIN: {}\n", seg.to_cin());
    println!("{}", emit_kernel(&sgap::compiler::lower(&seg)?));

    println!("==== Listing 5 analogue: {{<1/g row, c col>, r}} with atomicAddGroup ====\n");
    let row = Schedule::sgap_row_group(SpmmConfig { g: 32, ..cfg }, 8);
    println!("// CIN: {}\n", row.to_cin());
    println!("{}", emit_kernel(&sgap::compiler::lower(&row)?));

    println!("==== §5.3 macro instructions ====\n");
    println!("{}", macro_header());
    Ok(())
}
