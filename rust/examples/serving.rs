//! Serving-layer walkthrough: the multi-worker coordinator with its
//! tuner-aware plan cache, on mixed SpMM + SDDMM traffic.
//!
//! Eight client threads push repeated matrix shapes; the first sight of
//! each shape pays one selector decision (plan-cache miss) and enqueues a
//! background grid-search refinement; every repeat is a cache hit served
//! with the (eventually tuned) plan. The run ends with the service
//! metrics: per-backend latency histograms and cache counters.
//!
//! Run: `cargo run --release --example serving [-- --requests 200]`

use std::sync::Arc;

use sgap::coordinator::{Coordinator, CoordinatorConfig, Request};
use sgap::sparse::{erdos_renyi, power_law, SplitMix64};

fn main() -> anyhow::Result<()> {
    let per_client: usize = std::env::args()
        .skip_while(|a| a != "--requests")
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(50);

    let coord = Arc::new(Coordinator::start(CoordinatorConfig {
        workers: 4,
        background_tune: true,
        ..CoordinatorConfig::default()
    })?);
    println!("coordinator up: 4 workers, background tuner on");

    let clients = 8usize;
    let mut handles = Vec::new();
    for t in 0..clients {
        let coord = coord.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = SplitMix64::new(t as u64);
            for i in 0..per_client {
                // four repeated shapes: two uniform, one skewed, one SDDMM
                let shape = (t + i) % 4;
                let resp = match shape {
                    0 => {
                        let a = erdos_renyi(192, 192, 1800, 11).to_csr();
                        let b: Vec<f32> = (0..a.cols * 4).map(|_| rng.value()).collect();
                        coord.spmm_blocking(a, b, 4)
                    }
                    1 => {
                        let a = erdos_renyi(128, 128, 500, 12).to_csr();
                        let b: Vec<f32> = (0..a.cols * 8).map(|_| rng.value()).collect();
                        coord.spmm_blocking(a, b, 8)
                    }
                    2 => {
                        let a = power_law(192, 192, 2500, 1.9, 13).to_csr();
                        let b: Vec<f32> = (0..a.cols * 4).map(|_| rng.value()).collect();
                        coord.spmm_blocking(a, b, 4)
                    }
                    _ => {
                        let a = erdos_renyi(96, 96, 700, 14).to_csr();
                        let j = 16usize;
                        let x1: Vec<f32> = (0..a.rows * j).map(|_| rng.value()).collect();
                        let x2: Vec<f32> = (0..j * a.cols).map(|_| rng.value()).collect();
                        coord.sddmm_blocking(a, x1, x2, j)
                    }
                };
                let resp = resp.expect("request failed");
                if i == 0 {
                    println!(
                        "client {t}: first response via {} (plan {:?}, cache hit {})",
                        resp.backend, resp.plan, resp.cache_hit
                    );
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }

    let snap = coord.metrics.snapshot();
    println!(
        "\nserved {} requests, {} batches, p50 {} us p99 {} us",
        snap.completed, snap.batches, snap.p50_us, snap.p99_us
    );
    println!("plan cache: {} hits / {} misses", snap.cache_hits, snap.cache_misses);
    for b in &snap.backends {
        println!(
            "  {:<24} {:>6} reqs  p50 {:>8} us  p99 {:>8} us  mean {:>10.1} us",
            b.backend, b.count, b.p50_us, b.p99_us, b.mean_us
        );
    }

    let cache = coord.plan_cache.clone();
    Arc::try_unwrap(coord).ok().expect("all clients joined").shutdown();
    let cs = cache.stats();
    println!(
        "plan cache after shutdown: {} entries, {} tuned upgrades, {} evictions",
        cs.entries, cs.upgrades, cs.evictions
    );
    Ok(())
}
