//! Serving-layer walkthrough: the `Session` facade over the multi-worker
//! coordinator, on mixed SpMM + SDDMM traffic with **shared operand
//! handles**.
//!
//! Each repeated shape is registered exactly once — the fingerprint pass
//! runs at registration, and every one of the eight client threads then
//! submits zero-copy `Op`s against the same `Arc`-backed handles. The
//! first sight of each shape pays one selector decision (plan-cache miss)
//! and enqueues a background grid-search refinement; every repeat is a
//! cache hit served with the (eventually tuned) plan. The run ends with
//! the service metrics — and the handles' reference counts, back to
//! baseline: the proof that serving never cloned an operand.
//!
//! Run: `cargo run --release --example serving [-- --requests 200]`

use sgap::coordinator::{CoordinatorConfig, Op, Session};
use sgap::sparse::{erdos_renyi, power_law, SplitMix64};

fn main() -> anyhow::Result<()> {
    let per_client: usize = std::env::args()
        .skip_while(|a| a != "--requests")
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(50);

    let session = Session::start(CoordinatorConfig {
        workers: 4,
        background_tune: true,
        ..CoordinatorConfig::default()
    })?;
    println!("session up: 4 workers, background tuner on");

    // Register the four repeated shapes once: two uniform SpMM operand
    // sets, one skewed, one SDDMM. Registration runs the fingerprint
    // pass; everything after is Arc bumps.
    let mut rng = SplitMix64::new(99);
    let mut dense = |len: usize| -> Vec<f32> { (0..len).map(|_| rng.value()).collect() };

    let a0 = session.register_matrix(erdos_renyi(192, 192, 1800, 11).to_csr());
    let b0 = session.register_dense(dense(192 * 4));
    let a1 = session.register_matrix(erdos_renyi(128, 128, 500, 12).to_csr());
    let b1 = session.register_dense(dense(128 * 8));
    let a2 = session.register_matrix(power_law(192, 192, 2500, 1.9, 13).to_csr());
    let b2 = session.register_dense(dense(192 * 4));
    let a3 = session.register_matrix(erdos_renyi(96, 96, 700, 14).to_csr());
    let (j, rows, cols) = (16usize, 96usize, 96usize);
    let x1 = session.register_dense(dense(rows * j));
    let x2 = session.register_dense(dense(j * cols));

    let ops = [
        Op::spmm(&a0, &b0, 4),
        Op::spmm(&a1, &b1, 8),
        Op::spmm(&a2, &b2, 4),
        Op::sddmm(&a3, &x1, &x2, j),
    ];

    let clients = 8usize;
    let mut handles = Vec::new();
    for t in 0..clients {
        let session = session.clone();
        let ops = ops.clone();
        handles.push(std::thread::spawn(move || {
            for i in 0..per_client {
                // cloning an Op clones handles, not operands
                let op = ops[(t + i) % ops.len()].clone();
                let resp = session.submit(op).wait().expect("request failed");
                if i == 0 {
                    println!(
                        "client {t}: first response via {} (plan {:?}, cache hit {})",
                        resp.backend,
                        resp.plan_label(),
                        resp.cache_hit
                    );
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    drop(ops);

    let coord = session.coordinator();
    let snap = coord.metrics.snapshot();
    println!(
        "\nserved {} requests, {} batches, p50 {} us p99 {} us",
        snap.completed, snap.batches, snap.p50_us, snap.p99_us
    );
    println!("plan cache: {} hits / {} misses", snap.cache_hits, snap.cache_misses);
    for b in &snap.backends {
        println!(
            "  {:<24} {:>6} reqs  p50 {:>8} us  p99 {:>8} us  mean {:>10.1} us",
            b.backend, b.count, b.p50_us, b.p99_us, b.mean_us
        );
    }

    let cache = coord.plan_cache.clone();
    session.shutdown();
    println!(
        "operand refcounts after shutdown: a0 {}, b0 {} (1 = no clone ever escaped)",
        a0.strong_count(),
        b0.strong_count()
    );
    let cs = cache.stats();
    println!(
        "plan cache after shutdown: {} entries, {} tuned upgrades, {} evictions",
        cs.entries, cs.upgrades, cs.evictions
    );
    Ok(())
}
