//! Fig. 7/8 explorer: enumerate the atomic-parallelism space, show which
//! points the three rules prune and where the known algorithm families
//! (DA-SpMM, stock TACO, the two new Sgap algorithms) sit.
//!
//! Run: `cargo run --release --example space_explorer`

use sgap::compiler::spaces::{enumerate_all, AtomicPoint};

fn main() {
    let gs = [2u32, 4, 8, 16, 32];
    let cs = [2u32, 4, 8];
    let rs = [1u32, 2, 4, 8, 16, 32];
    let all = enumerate_all(&gs, &cs, &rs);
    let legal: Vec<_> = all.iter().filter(|(_, l)| l.is_ok()).collect();
    let mut by_rule: std::collections::BTreeMap<String, usize> = Default::default();
    for (_, l) in &all {
        if let Err(e) = l {
            *by_rule.entry(format!("{e:?}")).or_default() += 1;
        }
    }

    println!("atomic-parallelism space over g in {gs:?}, c in {cs:?}, r in {rs:?}");
    println!("  total points : {}", all.len());
    println!("  legal        : {}", legal.len());
    for (rule, n) in &by_rule {
        println!("  pruned by {rule}: {n}");
    }

    println!("\nknown algorithm families as points:");
    for (name, p) in AtomicPoint::da_spmm_embedding(4) {
        println!("  DA-SpMM {name:<8} {p}");
    }
    println!("  TACO   {{<g nnz,c col>,1}}   e.g. {}", AtomicPoint::eb_sr(4));
    println!("  TACO   {{<x row,c col>,1}}   e.g. {}", AtomicPoint::rb_sr(4));
    for r in [2u32, 8] {
        println!("  Sgap   new nnz point       {}", AtomicPoint::sgap_nnz(4, r));
    }
    for (g, r) in [(8u32, 8u32), (16, 32)] {
        println!("  Sgap   new row point       {}", AtomicPoint::sgap_row(g, 4, r));
    }

    println!("\npoints legal ONLY with Atomics races (rule-2 lift, §Table 1):");
    let mut shown = 0;
    for (p, l) in &all {
        if l.is_err() && p.is_legal_with_atomics() && shown < 8 {
            println!("  {p}");
            shown += 1;
        }
    }
}
