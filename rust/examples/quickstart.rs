//! Quickstart: the Sgap pipeline in ~40 lines.
//!
//! 1. Build an SpMM schedule with the new `GPUGroup` parallelize command.
//! 2. Lower it; print the generated CUDA-like kernel.
//! 3. Execute it on the SIMT simulator; check numerics vs the oracle and
//!    print the estimated kernel time on the paper's three GPUs.
//!
//! Run: `cargo run --release --example quickstart`

use sgap::algos::cpu_ref::{max_rel_err, spmm_serial};
use sgap::algos::runner::run_schedule;
use sgap::compiler::codegen_cuda::emit_kernel;
use sgap::compiler::schedule::{Schedule, SpmmConfig};
use sgap::sim::{HwProfile, Machine};
use sgap::sparse::{erdos_renyi, SplitMix64};

fn main() -> anyhow::Result<()> {
    // a 1024x1024 sparse matrix, N=4 dense columns
    let a = erdos_renyi(1024, 1024, 8192, 42).to_csr();
    let n = 4usize;
    let mut rng = SplitMix64::new(7);
    let b: Vec<f32> = (0..a.cols * n).map(|_| rng.value()).collect();

    // the paper's {<1 nnz, c col>, r} with segment reduction, r = 8
    let config = SpmmConfig { n: n as u32, c: 4, p: 256, g: 32, r: 8, x: 1 };
    let schedule = Schedule::sgap_nnz_group(config, 8);
    println!("CIN: {}\n", schedule.to_cin());

    let kernel = sgap::compiler::lower(&schedule)?;
    println!("{}", emit_kernel(&kernel));

    let want = spmm_serial(&a, &b, n);
    for hw in HwProfile::all() {
        let machine = Machine::new(hw);
        let run = run_schedule(&machine, &schedule, &a, &b)?;
        let err = max_rel_err(&run.c, &want);
        println!(
            "{:<11} {:>9.2} us  ({}-bound, {} warps, max rel err {err:.2e})",
            hw.name,
            run.report.time_s * 1e6,
            run.report.bound,
            run.report.warps
        );
        assert!(err < 1e-4);
    }
    println!("\nquickstart OK");
    Ok(())
}
