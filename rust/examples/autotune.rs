//! Autotuning walkthrough: sweep the atomic-parallelism space on one
//! matrix, compare the oracle-best against the input-dynamics selector
//! (DA-SpMM-style), and print where each algorithm family wins.
//!
//! Run: `cargo run --release --example autotune [-- dataset-name]`

use sgap::bench_util::random_b;
use sgap::sim::{HwProfile, Machine};
use sgap::sparse::{dataset, MatrixStats};
use sgap::tuner::{self, Selector};

fn main() -> anyhow::Result<()> {
    let name = std::env::args().nth(1).unwrap_or_else(|| "pl_2048_a1.6".into());
    let d = dataset::suite()
        .into_iter()
        .find(|d| d.name == name)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset {name}; see `sgap stats`"))?;
    let a = d.matrix.to_csr();
    let stats = MatrixStats::of(&a);
    println!("dataset {name}: {} x {}, nnz {}, degree cv {:.2}", a.rows, a.cols, a.nnz(), stats.row_degree_cv);

    let n = 4u32;
    let b = random_b(a.cols, n as usize, 9);
    let machine = Machine::new(HwProfile::rtx3090());

    let mut cands = tuner::space::taco_candidates(n);
    cands.extend(tuner::space::sgap_candidates(n));
    let out = tuner::tune(&machine, &cands, &a, &b, n)?;

    println!("\ntop 10 of {} candidates (RTX 3090):", out.ranked.len());
    for (alg, t, gf) in out.ranked.iter().take(10) {
        println!("  {:<36} {:>9.2} us {:>8.2} GFLOP/s", alg.name(), t * 1e6, gf);
    }
    let (best, t_best) = out.best().expect("non-empty sweep");

    let sel = Selector::default();
    let chosen = sel.select(&stats, n);
    let t_sel = chosen.run(&machine, &a, &b, n)?.time_s;
    println!("\noracle best : {:<36} {:>9.2} us", best.name(), t_best * 1e6);
    println!("selector    : {:<36} {:>9.2} us (regret {:.3}x)", chosen.name(), t_sel * 1e6, t_sel / t_best);

    // family winners
    for (label, pred) in [
        ("best stock-TACO", false),
        ("best segment-group", true),
    ] {
        let t = out
            .ranked
            .iter()
            .find(|(a, _, _)| {
                let is_sgap = matches!(
                    a,
                    sgap::algos::catalog::Algo::SgapRowGroup { .. }
                        | sgap::algos::catalog::Algo::SgapNnzGroup { .. }
                );
                is_sgap == pred
            })
            .map(|&(a, t, _)| (a, t));
        if let Some((a, t)) = t {
            println!("{label:<20}: {:<36} {:>9.2} us", a.name(), t * 1e6);
        }
    }
    Ok(())
}
