"""SDDMM Pallas kernel vs oracle — the §4.3 generalization at L1."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.sddmm import SddmmBucket, sddmm, sddmm_ref

RNG = np.random.default_rng(5)


def build(rows, cols, nnz, j, group, rng, bucket_nnz=None, tile=256):
    bucket_nnz = bucket_nnz or ((nnz + tile - 1) // tile + 1) * tile
    b = SddmmBucket(rows=rows, cols=cols, nnz=bucket_nnz, j=j, tile=tile, group=group)
    flat = rng.choice(rows * cols, size=nnz, replace=False)
    flat.sort()
    r = np.full(b.nnz, rows, np.int32)  # sentinel
    c = np.zeros(b.nnz, np.int32)
    v = np.zeros(b.nnz, np.float32)
    r[:nnz] = (flat // cols).astype(np.int32)
    c[:nnz] = (flat % cols).astype(np.int32)
    v[:nnz] = rng.standard_normal(nnz).astype(np.float32)
    x1 = np.zeros((rows + 1, j), np.float32)
    x1[:rows] = rng.standard_normal((rows, j)).astype(np.float32)  # sentinel row stays 0
    x2 = rng.standard_normal((j, cols)).astype(np.float32)
    return b, jnp.asarray(r), jnp.asarray(c), jnp.asarray(v), jnp.asarray(x1), jnp.asarray(x2)


def test_ref_matches_dense():
    b, r, c, v, x1, x2 = build(20, 24, 100, 16, 8, RNG)
    want_dense = (np.asarray(x1)[:-1] @ np.asarray(x2))  # (rows, cols)
    got = np.asarray(sddmm_ref(r, c, v, x1, x2))
    for p in range(100):
        i, k = int(r[p]), int(c[p])
        np.testing.assert_allclose(got[p], float(v[p]) * want_dense[i, k], rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("group", [2, 4, 8, 16, 32])
def test_kernel_group_sweep(group):
    j = max(group, 32)
    b, r, c, v, x1, x2 = build(48, 40, 300, j, group, RNG)
    got = sddmm(r, c, v, x1, x2, b)
    want = sddmm_ref(r, c, v, x1, x2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(
    rows=st.integers(8, 100),
    cols=st.integers(8, 100),
    j_chunks=st.integers(1, 4),
    group=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_hypothesis(rows, cols, j_chunks, group, seed):
    rng = np.random.default_rng(seed)
    nnz = min(rows * cols // 2, 200) or 1
    j = group * j_chunks
    b, r, c, v, x1, x2 = build(rows, cols, nnz, j, group, rng)
    got = sddmm(r, c, v, x1, x2, b)
    want = sddmm_ref(r, c, v, x1, x2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-4, atol=3e-4)


def test_padding_outputs_zero():
    b, r, c, v, x1, x2 = build(16, 16, 10, 8, 8, RNG)
    got = np.asarray(sddmm(r, c, v, x1, x2, b))
    assert np.all(got[10:] == 0.0), "padding slots must stay zero (sentinel row + zero vals)"
