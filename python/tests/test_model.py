"""L2 graph tests: GCN forward vs oracle + AOT lowering smoke tests."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import CooBucket, pad_coo, ref

RNG = np.random.default_rng(42)


def small_graph(rows, nnz, rng):
    """Random square adjacency in sorted COO with symmetric-ish structure."""
    flat = rng.choice(rows * rows, size=nnz, replace=False)
    flat.sort()
    r = (flat // rows).astype(np.int32)
    c = (flat % rows).astype(np.int32)
    v = (1.0 / np.sqrt(1 + rng.integers(1, 8, nnz))).astype(np.float32)
    return r, c, v


def test_gcn2_matches_ref():
    bucket = CooBucket(rows=128, cols=128, nnz=1024, n=8)
    r, c, v = small_graph(128, 700, RNG)
    pr, pc, pv = pad_coo(r, c, v, bucket)
    in_feat, hidden = 12, 8
    h = RNG.standard_normal((128, in_feat)).astype(np.float32)
    w1 = RNG.standard_normal((in_feat, hidden)).astype(np.float32)
    w2 = RNG.standard_normal((hidden, hidden)).astype(np.float32)

    fn = model.make_gcn2(bucket)
    (got,) = fn(pr, pc, pv, jnp.asarray(h), jnp.asarray(w1), jnp.asarray(w2))
    want = ref.gcn2_ref(pr, pc, pv, jnp.asarray(h), jnp.asarray(w1), jnp.asarray(w2), 128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_gcn2_nonnegative_output():
    """Final relu: outputs must be >= 0 (sanity on the graph structure)."""
    bucket = CooBucket(rows=64, cols=64, nnz=256, n=4)
    r, c, v = small_graph(64, 200, RNG)
    pr, pc, pv = pad_coo(r, c, v, bucket)
    h = RNG.standard_normal((64, 6)).astype(np.float32)
    w1 = RNG.standard_normal((6, 4)).astype(np.float32)
    w2 = RNG.standard_normal((4, 4)).astype(np.float32)
    (got,) = model.make_gcn2(bucket)(pr, pc, pv, jnp.asarray(h), jnp.asarray(w1), jnp.asarray(w2))
    assert np.all(np.asarray(got) >= 0)


def test_gcn_example_args_shape_guard():
    bucket = CooBucket(rows=64, cols=64, nnz=256, n=4)
    with pytest.raises(AssertionError):
        model.gcn2_example_args(bucket, in_feat=8, hidden=5, out_feat=4)


# ---------------------------------------------------------------------------
# AOT lowering: every registry entry must lower to parseable HLO text.
# ---------------------------------------------------------------------------


def test_registry_names_unique_and_stable():
    reg = aot.build_registry()
    assert "gcn2" in reg
    assert any(k.startswith("spmm_nnz_sr") for k in reg)
    assert any(k.startswith("spmm_row_pr") for k in reg)
    # group variants present (the paper's r sweep)
    assert aot.coo_name(dataclasses.replace(aot.COO_SMALL, group=8)) in reg


@pytest.mark.parametrize("name", sorted(aot.build_registry().keys()))
def test_lowering_produces_hlo_text(name):
    fn, example_args, meta = aot.build_registry()[name]
    text = aot.to_hlo_text(jax.jit(fn).lower(*example_args))
    assert "HloModule" in text
    assert "ROOT" in text
    # return_tuple=True: root must be a tuple
    assert meta["kind"] in ("spmm_nnz_sr", "spmm_row_pr", "gcn2")


def test_lowered_spmm_executes_like_eager():
    """jit-lowered artifact == eager kernel on the same inputs."""
    bucket = aot.COO_SMALL
    fn = model.make_spmm_nnz_sr(bucket)
    r, c, v = small_graph(bucket.rows, 2000, RNG)
    pr, pc, pv = pad_coo(r, c, v, bucket)
    b = RNG.standard_normal((bucket.cols, bucket.n)).astype(np.float32)
    (eager,) = fn(pr, pc, pv, jnp.asarray(b))
    (jitted,) = jax.jit(fn)(pr, pc, pv, jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(eager), np.asarray(jitted), rtol=1e-5, atol=1e-5)
