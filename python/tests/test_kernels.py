"""Kernel-vs-oracle correctness: the CORE numeric signal for L1.

Every Pallas kernel is checked against the pure-jnp ref (which is itself
checked against a dense matmul), over hypothesis-generated random sparse
matrices and the full sweep of group sizes / tile shapes the paper tunes.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import (
    CooBucket,
    EllBucket,
    pad_coo,
    pad_ell,
    ref,
    spmm_nnz_sr,
    spmm_row_pr,
)

RNG = np.random.default_rng(0)


def random_coo(rows, cols, nnz, rng):
    """Random COO sorted by (row, col), unique coordinates."""
    # sample without replacement from the flat index space
    flat = rng.choice(rows * cols, size=min(nnz, rows * cols), replace=False)
    flat.sort()
    r = (flat // cols).astype(np.int32)
    c = (flat % cols).astype(np.int32)
    v = rng.standard_normal(len(flat)).astype(np.float32)
    return r, c, v


def coo_to_csr(r, c, v, rows):
    indptr = np.zeros(rows + 1, np.int64)
    np.add.at(indptr, r + 1, 1)
    indptr = np.cumsum(indptr)
    return indptr, c, v


# ---------------------------------------------------------------------------
# Oracle self-check: segment_sum ref == dense matmul.
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    rows=st.integers(4, 64),
    cols=st.integers(4, 64),
    n=st.integers(1, 8),
    density=st.floats(0.01, 0.5),
    seed=st.integers(0, 2**31 - 1),
)
def test_ref_matches_dense(rows, cols, n, density, seed):
    rng = np.random.default_rng(seed)
    nnz = max(1, int(rows * cols * density))
    r, c, v = random_coo(rows, cols, nnz, rng)
    b = rng.standard_normal((cols, n)).astype(np.float32)
    dense = np.asarray(ref.coo_to_dense(jnp.asarray(r), jnp.asarray(c), jnp.asarray(v), rows, cols))
    want = dense @ b
    got = ref.spmm_coo_ref(jnp.asarray(r), jnp.asarray(c), jnp.asarray(v), jnp.asarray(b), rows)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# spmm_nnz_sr (segment reduction) vs ref — sweep group sizes and tiles.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("group", [2, 4, 8, 16, 32, 64])
@pytest.mark.parametrize("tile", [64, 256])
def test_nnz_sr_group_sweep(group, tile):
    if tile % group != 0:
        pytest.skip("tile must be group-aligned")
    rows, cols, n = 128, 96, 4
    bucket = CooBucket(rows=rows, cols=cols, nnz=1024, n=n, tile=tile, group=group)
    r, c, v = random_coo(rows, cols, 700, RNG)
    b = RNG.standard_normal((cols, n)).astype(np.float32)
    pr, pc, pv = pad_coo(r, c, v, bucket)
    got = spmm_nnz_sr(pr, pc, pv, jnp.asarray(b), bucket)
    want = ref.spmm_coo_ref(pr, pc, pv, jnp.asarray(b), rows)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(
    rows=st.integers(8, 200),
    cols=st.integers(8, 200),
    n=st.sampled_from([1, 2, 4, 7, 16]),
    density=st.floats(0.005, 0.3),
    group=st.sampled_from([2, 8, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_nnz_sr_hypothesis(rows, cols, n, density, group, seed):
    rng = np.random.default_rng(seed)
    nnz = max(1, int(rows * cols * density))
    bucket_nnz = ((nnz + 255) // 256 + 1) * 256
    bucket = CooBucket(rows=rows, cols=cols, nnz=bucket_nnz, n=n, tile=256, group=group)
    r, c, v = random_coo(rows, cols, nnz, rng)
    b = rng.standard_normal((cols, n)).astype(np.float32)
    pr, pc, pv = pad_coo(r, c, v, bucket)
    got = spmm_nnz_sr(pr, pc, pv, jnp.asarray(b), bucket)
    want = ref.spmm_coo_ref(pr, pc, pv, jnp.asarray(b), rows)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)


def test_nnz_sr_empty_matrix():
    """All-padding bucket must produce exactly zero output."""
    bucket = CooBucket(rows=32, cols=32, nnz=256, n=4)
    pr, pc, pv = pad_coo(np.empty(0, np.int32), np.empty(0, np.int32), np.empty(0, np.float32), bucket)
    b = np.ones((32, 4), np.float32)
    got = spmm_nnz_sr(pr, pc, pv, jnp.asarray(b), bucket)
    assert np.all(np.asarray(got) == 0)


def test_nnz_sr_single_long_row():
    """One row owning every nnz: the worst case for segment boundaries."""
    bucket = CooBucket(rows=8, cols=64, nnz=256, n=4, group=16)
    c = np.arange(64, dtype=np.int32)
    r = np.zeros(64, np.int32)
    v = np.ones(64, np.float32)
    b = RNG.standard_normal((64, 4)).astype(np.float32)
    pr, pc, pv = pad_coo(r, c, v, bucket)
    got = spmm_nnz_sr(pr, pc, pv, jnp.asarray(b), bucket)
    np.testing.assert_allclose(np.asarray(got)[0], b.sum(axis=0), rtol=2e-5, atol=2e-5)
    assert np.all(np.asarray(got)[1:] == 0)


def test_nnz_sr_row_per_element():
    """Every nnz its own row: every lane is a writeback lane."""
    bucket = CooBucket(rows=256, cols=16, nnz=256, n=2, group=32)
    r = np.arange(200, dtype=np.int32)
    c = (np.arange(200) % 16).astype(np.int32)
    v = RNG.standard_normal(200).astype(np.float32)
    b = RNG.standard_normal((16, 2)).astype(np.float32)
    pr, pc, pv = pad_coo(r, c, v, bucket)
    got = spmm_nnz_sr(pr, pc, pv, jnp.asarray(b), bucket)
    want = ref.spmm_coo_ref(pr, pc, pv, jnp.asarray(b), 256)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_nnz_sr_segment_straddles_tiles():
    """A row whose nnz span a tile boundary must still sum correctly
    (the cross-tile combine is the epilogue's job)."""
    bucket = CooBucket(rows=4, cols=512, nnz=512, n=1, tile=256, group=32)
    r = np.zeros(400, np.int32)  # row 0 spans tiles 0 and 1
    c = np.arange(400, dtype=np.int32)
    v = np.ones(400, np.float32)
    b = np.ones((512, 1), np.float32)
    pr, pc, pv = pad_coo(r, c, v, bucket)
    got = spmm_nnz_sr(pr, pc, pv, jnp.asarray(b), bucket)
    assert np.isclose(np.asarray(got)[0, 0], 400.0)


# ---------------------------------------------------------------------------
# spmm_row_pr (parallel reduction over ELL) vs ref.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("group", [2, 4, 8, 16, 32])
def test_row_pr_group_sweep(group):
    rows, cols, n, slots = 128, 96, 4, 32
    bucket = EllBucket(rows=rows, cols=cols, slots=slots, n=n, row_tile=32, group=group)
    r, c, v = random_coo(rows, cols, 600, RNG)
    # clamp row degree to slots
    keep = np.zeros(len(r), bool)
    counts = {}
    for i, ri in enumerate(r):
        if counts.get(ri, 0) < slots:
            keep[i] = True
            counts[ri] = counts.get(ri, 0) + 1
    r, c, v = r[keep], c[keep], v[keep]
    indptr, idx, data = coo_to_csr(r, c, v, rows)
    b = RNG.standard_normal((cols, n)).astype(np.float32)
    cols_p, vals_p = pad_ell(indptr, idx, data, bucket)
    got = spmm_row_pr(cols_p, vals_p, jnp.asarray(b), bucket)
    want = ref.spmm_ell_ref(cols_p, vals_p, jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(
    rows=st.sampled_from([32, 64, 128]),
    n=st.sampled_from([1, 4, 8]),
    slots=st.sampled_from([8, 16, 32]),
    group=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_row_pr_hypothesis(rows, n, slots, group, seed):
    rng = np.random.default_rng(seed)
    cols = rows
    bucket = EllBucket(rows=rows, cols=cols, slots=slots, n=n, row_tile=32, group=group)
    # random per-row degrees <= slots
    deg = rng.integers(0, slots + 1, size=rows)
    indptr = np.zeros(rows + 1, np.int64)
    indptr[1:] = np.cumsum(deg)
    idx = rng.integers(0, cols, size=indptr[-1]).astype(np.int32)
    data = rng.standard_normal(indptr[-1]).astype(np.float32)
    b = rng.standard_normal((cols, n)).astype(np.float32)
    cols_p, vals_p = pad_ell(indptr, idx, data, bucket)
    got = spmm_row_pr(cols_p, vals_p, jnp.asarray(b), bucket)
    want = ref.spmm_ell_ref(cols_p, vals_p, jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)


def test_row_pr_matches_nnz_sr():
    """The two kernels are different algorithms for the same algebra —
    cross-check them against each other on the same matrix."""
    rows = cols = 128
    n = 4
    r, c, v = random_coo(rows, cols, 500, np.random.default_rng(7))
    b = RNG.standard_normal((cols, n)).astype(np.float32)

    coo_b = CooBucket(rows=rows, cols=cols, nnz=512, n=n)
    pr, pc, pv = pad_coo(r, c, v, coo_b)
    out_sr = spmm_nnz_sr(pr, pc, pv, jnp.asarray(b), coo_b)

    indptr, idx, data = coo_to_csr(r, c, v, rows)
    ell_b = EllBucket(rows=rows, cols=cols, slots=32, n=n, row_tile=32)
    cols_p, vals_p = pad_ell(indptr, idx, data, ell_b)
    out_pr = spmm_row_pr(cols_p, vals_p, jnp.asarray(b), ell_b)

    np.testing.assert_allclose(np.asarray(out_sr), np.asarray(out_pr), rtol=3e-5, atol=3e-5)
