from .common import CooBucket, EllBucket, pad_coo, pad_ell, row_pad_sentinel
from .spmm_nnz_sr import spmm_nnz_sr, spmm_block_partials
from .spmm_row_pr import spmm_row_pr
from .sddmm import SddmmBucket, sddmm, sddmm_ref
from . import ref

__all__ = [
    "CooBucket",
    "EllBucket",
    "pad_coo",
    "pad_ell",
    "row_pad_sentinel",
    "spmm_nnz_sr",
    "spmm_block_partials",
    "spmm_row_pr",
    "ref",
]
