"""L1 Pallas kernel: nnz-balanced SpMM with grouped *segment reduction*.

This is the TPU adaptation of the paper's ``{<1 nnz, c col>, r}`` algorithm
(Listing 6): every "thread" owns one non-zero; ``r`` threads synchronize; the
writeback threads are decided at runtime by segment boundaries (segment
reduction), because a group may straddle several sparse rows.

GPU -> TPU mapping (DESIGN.md §Hardware-Adaptation):

* warp shuffle (``__shfl_up_sync``) segmented scan  ->  log2-step *rolled*
  segmented inclusive scan over a ``TILE`` block held in VMEM;
* reduction parallelism ``r`` (= ``bucket.group``)  ->  the scan **span**:
  lanes are grouped in chunks of ``r``; scan never crosses a chunk
  boundary, exactly like a shuffle with group size ``r``;
* ``segReduceWarp``'s runtime-decided writeback threads  ->  a segment-end
  mask: only lanes that terminate a (row, group) segment emit their total,
  all other lanes emit 0;
* the cross-group combine (``atomicAdd`` of group totals on GPU)  ->  an XLA
  ``segment_sum`` epilogue over the masked block outputs (TPU has no HBM
  atomics; scatter-add is the idiomatic writeback).
* the paper's *zero extension* (§5.2)  ->  padding non-zeros carry
  ``val == 0`` and run through the scan branch-free instead of being
  guarded out.

The kernel is lowered with ``interpret=True`` (CPU-PJRT executable HLO);
real-TPU performance is estimated in DESIGN.md from the VMEM footprint:
``TILE*(4+4+4) + K*N*4 + TILE*N*4`` bytes per instance.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import CooBucket


def _seg_scan_kernel(row_ref, col_ref, val_ref, b_ref, o_ref, *, tile: int, group: int):
    """One grid step: scan `tile` non-zeros, emit masked segment totals."""
    r = row_ref[...]                       # (tile,) int32 row ids (sentinel-padded)
    c = col_ref[...]                       # (tile,) int32 col ids
    v = val_ref[...]                       # (tile,) f32 values (0 on padding)
    b = b_ref[...]                         # (K, N) dense matrix, staged per block

    # Each lane's contribution: v[k] * B[c[k], :]  — the multiply half of
    # the reduction; gather is XLA `gather` under interpret mode.
    contrib = v[:, None] * jnp.take(b, c, axis=0)          # (tile, N)

    # Grouped segmented inclusive scan (Hillis–Steele), span = `group`.
    lane = jax.lax.iota(jnp.int32, tile) % group
    x = contrib
    d = 1
    while d < group:
        shifted = jnp.roll(x, d, axis=0)
        same_row = r == jnp.roll(r, d)
        in_span = lane >= d                 # never cross the group boundary
        x = x + jnp.where((same_row & in_span)[:, None], shifted, 0.0)
        d *= 2

    # Writeback lanes: last lane of the group, or the row changes next lane.
    nxt = jnp.roll(r, -1)
    is_end = (lane == group - 1) | (r != nxt)
    o_ref[...] = jnp.where(is_end[:, None], x, 0.0)


def spmm_block_partials(row_idx, col_idx, vals, b, bucket: CooBucket):
    """Run the Pallas scan over all nnz tiles; returns (nnz, N) masked totals."""
    tile, group, n = bucket.tile, bucket.group, bucket.n
    kernel = functools.partial(_seg_scan_kernel, tile=tile, group=group)
    return pl.pallas_call(
        kernel,
        grid=(bucket.nnz // tile,),
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((bucket.cols, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bucket.nnz, n), jnp.float32),
        interpret=True,
    )(row_idx, col_idx, vals, b)


def spmm_nnz_sr(row_idx, col_idx, vals, b, bucket: CooBucket):
    """Full SpMM: Pallas grouped segment scan + scatter-add epilogue.

    The epilogue sums at most ``nnz/group + #rows`` non-zero entries — it is
    the TPU analogue of the per-group ``atomicAdd`` writeback.
    """
    partials = spmm_block_partials(row_idx, col_idx, vals, b, bucket)
    out = jax.ops.segment_sum(partials, row_idx, num_segments=bucket.rows + 1)
    return out[: bucket.rows]
