"""L1 Pallas kernel: row-balanced SpMM with grouped *parallel reduction*.

TPU adaptation of the paper's ``{<1/g row, c col>, r}`` algorithm
(Listing 5): ``g`` threads cooperate on one sparse row, synchronizing in
groups of ``r`` with a tree (parallel) reduction — exactly one writeback
thread per row.

GPU -> TPU mapping (DESIGN.md §Hardware-Adaptation):

* the sparse matrix is staged as padded ELL (``cols/vals[rows, slots]``),
  the TPU analogue of assigning ``g`` lanes per row: the ``slots`` axis is
  the lane axis of the cooperating group;
* the ``log2(r)`` shuffle tree of ``atomicAddGroup``  ->  a halving tree
  reduction over chunks of ``r`` slots in VMEM;
* ``g/r`` serial chunk accumulation (when the group is smaller than the
  row's lane count)  ->  a sum over the ``slots/r`` chunk axis;
* exactly one writeback per row (parallel reduction's single writeback
  thread)  ->  the kernel writes the C tile directly, no epilogue.

Padding slots carry ``val == 0`` — the zero-extension trick again: they
flow through the tree instead of being guarded by control flow.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import EllBucket


def _row_tree_kernel(col_ref, val_ref, b_ref, o_ref, *, group: int):
    cols = col_ref[...]                      # (row_tile, slots)
    vals = val_ref[...]                      # (row_tile, slots)
    b = b_ref[...]                           # (K, N)

    gathered = jnp.take(b, cols, axis=0)     # (row_tile, slots, N)
    x = vals[..., None] * gathered           # (row_tile, slots, N)

    # Chunk the slot axis into groups of `group` lanes …
    rt, slots, n = x.shape
    x = x.reshape(rt, slots // group, group, n)
    # … tree-reduce inside each group (log2(r) steps, like shfl_down) …
    d = group // 2
    while d >= 1:
        x = x[:, :, :d, :] + x[:, :, d : 2 * d, :]
        d //= 2
    # … then serially accumulate the g/r chunks; single writeback per row.
    o_ref[...] = x[:, :, 0, :].sum(axis=1)


def spmm_row_pr(cols, vals, b, bucket: EllBucket):
    """Full SpMM over the ELL bucket; returns (rows, N)."""
    kernel = functools.partial(_row_tree_kernel, group=bucket.group)
    rt, n = bucket.row_tile, bucket.n
    return pl.pallas_call(
        kernel,
        grid=(bucket.rows // rt,),
        in_specs=[
            pl.BlockSpec((rt, bucket.slots), lambda i: (i, 0)),
            pl.BlockSpec((rt, bucket.slots), lambda i: (i, 0)),
            pl.BlockSpec((bucket.cols, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rt, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bucket.rows, n), jnp.float32),
        interpret=True,
    )(cols, vals, b)
