"""Shared helpers for the Sgap Pallas kernels.

Padding/bucketing: HLO artifacts are shape-static, so sparse inputs are
padded to fixed *buckets* before entering the kernels. The rust runtime
(`rust/src/runtime/artifact.rs`) performs the same padding; the constants
here are the single source of truth and are exported into
``artifacts/manifest.json`` by ``aot.py``.

Conventions
-----------
* COO bucket: ``row_idx[i] == ROW_PAD_SENTINEL`` marks padding. Padding
  entries carry ``val == 0`` and ``col_idx == 0`` so they are numerically
  inert even when the segmented scan runs over them (the paper's *zero
  extension*: out-of-bound reduction elements are allowed because warp
  primitives run branch-free — §5.2).
* ELL bucket: per-row slots beyond the true degree carry ``col == 0`` and
  ``val == 0``.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

# Padding rows index one past the real row range; the epilogue drops them.
def row_pad_sentinel(num_rows_padded: int) -> int:
    return num_rows_padded  # one extra segment id, sliced off after segment_sum


@dataclasses.dataclass(frozen=True)
class CooBucket:
    """Static shapes for a COO (nnz-major) SpMM artifact."""

    rows: int      # padded number of sparse-matrix rows (output rows)
    cols: int      # padded number of sparse-matrix cols (== dense B rows)
    nnz: int       # padded nnz, multiple of tile
    n: int         # dense column count N
    tile: int = 256    # nnz block processed per kernel instance
    group: int = 32    # reduction parallelism r: segmented-scan span

    def __post_init__(self):
        assert self.nnz % self.tile == 0, "nnz bucket must be tile-aligned"
        assert self.tile % self.group == 0, "tile must be group-aligned"
        assert self.group & (self.group - 1) == 0, "group must be a power of 2"


@dataclasses.dataclass(frozen=True)
class EllBucket:
    """Static shapes for an ELL (row-major) SpMM artifact."""

    rows: int      # padded rows
    cols: int      # padded cols (dense B rows)
    slots: int     # padded max row degree, multiple of group
    n: int
    row_tile: int = 64   # rows per kernel instance
    group: int = 32      # reduction parallelism r: tree-reduce span over slots

    def __post_init__(self):
        assert self.rows % self.row_tile == 0
        assert self.slots % self.group == 0
        assert self.group & (self.group - 1) == 0


def pad_coo(row, col, val, bucket: CooBucket):
    """Pad COO arrays (sorted by row) to the bucket's static nnz."""
    row = np.asarray(row, np.int32)
    col = np.asarray(col, np.int32)
    val = np.asarray(val, np.float32)
    nnz = row.shape[0]
    assert nnz <= bucket.nnz, f"nnz {nnz} exceeds bucket {bucket.nnz}"
    sent = row_pad_sentinel(bucket.rows)
    pr = np.full(bucket.nnz, sent, np.int32)
    pc = np.zeros(bucket.nnz, np.int32)
    pv = np.zeros(bucket.nnz, np.float32)
    pr[:nnz], pc[:nnz], pv[:nnz] = row, col, val
    return jnp.asarray(pr), jnp.asarray(pc), jnp.asarray(pv)


def pad_ell(indptr, indices, data, bucket: EllBucket):
    """CSR -> padded ELL (cols[rows, slots], vals[rows, slots])."""
    indptr = np.asarray(indptr, np.int64)
    rows = indptr.shape[0] - 1
    assert rows <= bucket.rows
    cols = np.zeros((bucket.rows, bucket.slots), np.int32)
    vals = np.zeros((bucket.rows, bucket.slots), np.float32)
    for i in range(rows):
        lo, hi = indptr[i], indptr[i + 1]
        deg = hi - lo
        assert deg <= bucket.slots, f"row {i} degree {deg} > slots {bucket.slots}"
        cols[i, :deg] = indices[lo:hi]
        vals[i, :deg] = data[lo:hi]
    return jnp.asarray(cols), jnp.asarray(vals)
