"""Pure-jnp oracles for the Sgap SpMM kernels.

These are the correctness references the Pallas kernels are tested against
(pytest + hypothesis in ``python/tests/``). They use only dense jnp /
``segment_sum`` primitives with no tiling, so any structural bug in the
kernels (scan span, group boundary, padding sentinel) shows up as a
numeric mismatch.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import CooBucket, EllBucket


def spmm_coo_ref(row_idx, col_idx, vals, b, num_rows_padded: int):
    """C[i, :] = sum_{k: row[k]==i} vals[k] * B[col[k], :].

    ``row_idx`` may contain the padding sentinel ``num_rows_padded``; the
    extra segment is computed then sliced off, mirroring zero extension.
    """
    contrib = vals[:, None] * b[col_idx, :]              # (nnz, N)
    out = jax.ops.segment_sum(contrib, row_idx, num_segments=num_rows_padded + 1)
    return out[:num_rows_padded]


def spmm_ell_ref(cols, vals, b):
    """C[i, :] = sum_s vals[i, s] * B[cols[i, s], :] (padding slots are 0)."""
    gathered = b[cols, :]                                # (rows, slots, N)
    return jnp.einsum("rs,rsn->rn", vals, gathered)


def spmm_dense_ref(a_dense, b):
    """Dense matmul oracle used by the property tests to check the refs."""
    return a_dense @ b


def coo_to_dense(row_idx, col_idx, vals, rows, cols):
    a = jnp.zeros((rows + 1, cols), vals.dtype)          # +1 = sentinel row
    a = a.at[row_idx, col_idx].add(vals)
    return a[:rows]


def gcn2_ref(row_idx, col_idx, vals, h, w1, w2, num_rows_padded: int):
    """Two-layer GCN forward: relu(Â (relu(Â H W1)) W2)."""
    z1 = spmm_coo_ref(row_idx, col_idx, vals, h @ w1, num_rows_padded)
    h1 = jax.nn.relu(z1)
    z2 = spmm_coo_ref(row_idx, col_idx, vals, h1 @ w2, num_rows_padded)
    return jax.nn.relu(z2)


__all__ = [
    "spmm_coo_ref",
    "spmm_ell_ref",
    "spmm_dense_ref",
    "coo_to_dense",
    "gcn2_ref",
    "CooBucket",
    "EllBucket",
]
