"""L1 Pallas kernel: SDDMM with grouped tree reduction — the §4.3
generalization of segment group beyond SpMM.

``Y[p] = A_vals[p] * sum_j X1[row[p], j] * X2[j, col[p]]`` over the sparse
pattern. The reduction (over the dense ``j``) reuses exactly the grouped
tree-reduce structure of ``spmm_row_pr``: chunks of ``group`` lanes are
tree-halved (the ``atomicAddGroup`` analogue), then chunk partials sum
serially. Zero extension pads ``j`` to a group multiple with zeros and
rows with a sentinel row of zeros in X1.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


@dataclasses.dataclass(frozen=True)
class SddmmBucket:
    rows: int      # padded rows of A (X1 gets one extra sentinel row)
    cols: int      # padded cols of A (== X2 cols)
    nnz: int       # padded nnz, multiple of tile
    j: int         # dense reduction dim, multiple of group
    tile: int = 256
    group: int = 32

    def __post_init__(self):
        assert self.nnz % self.tile == 0
        assert self.j % self.group == 0, "pad J to a group multiple (zero extension)"
        assert self.group & (self.group - 1) == 0


def _sddmm_kernel(row_ref, col_ref, val_ref, x1_ref, x2_ref, o_ref, *, group: int):
    r = row_ref[...]                    # (tile,) int32, sentinel = rows
    c = col_ref[...]                    # (tile,)
    v = val_ref[...]                    # (tile,)
    x1 = x1_ref[...]                    # (rows + 1, J) — sentinel row is zeros
    x2 = x2_ref[...]                    # (J, cols)

    g1 = jnp.take(x1, r, axis=0)        # (tile, J)
    g2 = jnp.take(x2, c, axis=1).T      # (tile, J)
    x = g1 * g2                         # per-lane partial products

    # grouped tree reduction over j (same shape as spmm_row_pr's reduce)
    tile, jdim = x.shape
    x = x.reshape(tile, jdim // group, group)
    d = group // 2
    while d >= 1:
        x = x[:, :, :d] + x[:, :, d : 2 * d]
        d //= 2
    dot = x[:, :, 0].sum(axis=1)        # serial chunk accumulation
    o_ref[...] = v * dot


def sddmm(row_idx, col_idx, vals, x1, x2, bucket: SddmmBucket):
    """Padded SDDMM: returns (nnz,) outputs (padding slots are 0)."""
    assert x1.shape == (bucket.rows + 1, bucket.j), "X1 must carry the sentinel row"
    assert x2.shape == (bucket.j, bucket.cols)
    kernel = functools.partial(_sddmm_kernel, group=bucket.group)
    t = bucket.tile
    return pl.pallas_call(
        kernel,
        grid=(bucket.nnz // t,),
        in_specs=[
            pl.BlockSpec((t,), lambda i: (i,)),
            pl.BlockSpec((t,), lambda i: (i,)),
            pl.BlockSpec((t,), lambda i: (i,)),
            pl.BlockSpec((bucket.rows + 1, bucket.j), lambda i: (0, 0)),
            pl.BlockSpec((bucket.j, bucket.cols), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((t,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((bucket.nnz,), jnp.float32),
        interpret=True,
    )(row_idx, col_idx, vals, x1, x2)


def sddmm_ref(row_idx, col_idx, vals, x1, x2):
    """Pure-jnp oracle (same padded signature)."""
    g1 = jnp.take(x1, row_idx, axis=0)
    g2 = jnp.take(x2, col_idx, axis=1).T
    return vals * (g1 * g2).sum(axis=1)
