"""AOT compile path: lower every L2 graph to HLO *text* artifacts.

Run once at build time (``make artifacts``); the rust runtime loads the
text with ``HloModuleProto::from_text_file`` and executes via PJRT. HLO
text — NOT ``lowered.compiler_ir("hlo").as_serialized_hlo_module_proto()``
— is the interchange format because jax >= 0.5 emits protos with 64-bit
instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md).

Also writes ``artifacts/manifest.json`` describing each artifact's
signature and bucket parameters; ``rust/src/runtime/artifact.rs`` is the
consumer and must stay in sync.

Usage: ``python -m compile.aot --out-dir ../artifacts``
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model
from .kernels import CooBucket, EllBucket


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# Artifact registry — the single place new artifacts are declared.
# ---------------------------------------------------------------------------

# Default buckets: small enough to compile in seconds, big enough for the
# e2e example (Cora-scale graph: 2708 rows / ~13k nnz after padding).
COO_SMALL = CooBucket(rows=512, cols=512, nnz=4096, n=4, tile=256, group=32)
GCN_BUCKET = CooBucket(rows=4096, cols=4096, nnz=16384, n=16, tile=256, group=32)


def coo_name(b: CooBucket) -> str:
    return f"spmm_nnz_sr_r{b.rows}_z{b.nnz}_n{b.n}_g{b.group}"


def ell_name(b: EllBucket) -> str:
    return f"spmm_row_pr_r{b.rows}_s{b.slots}_n{b.n}_g{b.group}"


def build_registry():
    """name -> (callable, example_args, manifest entry)."""
    reg = {}

    for group in (8, 32):
        b = dataclasses.replace(COO_SMALL, group=group)
        reg[coo_name(b)] = (
            model.make_spmm_nnz_sr(b),
            model.spmm_nnz_example_args(b),
            {"kind": "spmm_nnz_sr", **dataclasses.asdict(b)},
        )
        e = EllBucket(rows=512, cols=512, slots=32, n=4, row_tile=64, group=group)
        reg[ell_name(e)] = (
            model.make_spmm_row_pr(e),
            model.spmm_ell_example_args(e),
            {"kind": "spmm_row_pr", **dataclasses.asdict(e)},
        )

    in_feat, hidden, out_feat = 64, 16, 16
    reg["gcn2"] = (
        model.make_gcn2(GCN_BUCKET),
        model.gcn2_example_args(GCN_BUCKET, in_feat, hidden, out_feat),
        {
            "kind": "gcn2",
            **dataclasses.asdict(GCN_BUCKET),
            "in_feat": in_feat,
            "hidden": hidden,
            "out_feat": out_feat,
        },
    )
    return reg


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="build a single named artifact")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {}
    for name, (fn, example_args, meta) in sorted(build_registry().items()):
        if args.only and name != args.only:
            continue
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        text = to_hlo_text(jax.jit(fn).lower(*example_args))
        with open(path, "w") as f:
            f.write(text)
        arg_sig = [[list(a.shape), a.dtype.name] for a in example_args]
        manifest[name] = {**meta, "file": f"{name}.hlo.txt", "args": arg_sig}
        print(f"aot: {name}: {len(text)} chars -> {path}")

    mpath = os.path.join(args.out_dir, "manifest.json")
    if not args.only:
        with open(mpath, "w") as f:
            json.dump(manifest, f, indent=2, sort_keys=True)
        print(f"aot: manifest -> {mpath}")


if __name__ == "__main__":
    main()
