"""L2: jax compute graphs built on the Sgap Pallas kernels.

Every public function here is a *pure* jax function of arrays only — the
shapes are frozen by the bucket passed at build time, so ``aot.py`` can
``jax.jit(...).lower(...)`` each one into a standalone HLO artifact that the
rust runtime executes via PJRT. Python never runs at serve time.

Artifacts
---------
* ``spmm_nnz_sr``  — the segment-group SpMM (paper's ``{<1 nnz,c col>,r}``)
* ``spmm_row_pr``  — the grouped parallel-reduction SpMM
  (paper's ``{<1/g row,c col>,r}``)
* ``gcn2``         — 2-layer GCN forward whose aggregation is the
  segment-group SpMM; the end-to-end workload of ``examples/e2e_gcn.rs``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import CooBucket, EllBucket, spmm_nnz_sr, spmm_row_pr


def make_spmm_nnz_sr(bucket: CooBucket):
    """SpMM via grouped segment reduction. Args: row, col, val, B."""

    def fn(row_idx, col_idx, vals, b):
        return (spmm_nnz_sr(row_idx, col_idx, vals, b, bucket),)

    return fn


def make_spmm_row_pr(bucket: EllBucket):
    """SpMM via grouped parallel reduction over ELL. Args: cols, vals, B."""

    def fn(cols, vals, b):
        return (spmm_row_pr(cols, vals, b, bucket),)

    return fn


def make_gcn2(bucket: CooBucket):
    """2-layer GCN forward; Â is the bucketed sparse matrix.

    ``H' = relu(Â · relu(Â · H·W1) · W2)`` — both aggregations go through
    the segment-group SpMM kernel, so the hot op in the artifact is the
    paper's kernel, not a dense matmul.
    """

    def fn(row_idx, col_idx, vals, h, w1, w2):
        z1 = spmm_nnz_sr(row_idx, col_idx, vals, h @ w1, bucket)
        h1 = jax.nn.relu(z1)
        z2 = spmm_nnz_sr(row_idx, col_idx, vals, h1 @ w2, bucket)
        return (jax.nn.relu(z2),)

    return fn


def gcn2_example_args(bucket: CooBucket, in_feat: int, hidden: int, out_feat: int):
    """ShapeDtypeStructs matching ``make_gcn2``'s signature.

    The GCN aggregates (rows, hidden)-shaped activations, so the bucket's
    ``n`` must equal ``hidden`` and ``out_feat`` — callers assert this.
    """
    assert bucket.n == hidden == out_feat, "gcn artifact: bucket.n == hidden == out_feat"
    assert bucket.cols == bucket.rows, "gcn adjacency is square"
    i32, f32 = jnp.int32, jnp.float32
    return (
        jax.ShapeDtypeStruct((bucket.nnz,), i32),
        jax.ShapeDtypeStruct((bucket.nnz,), i32),
        jax.ShapeDtypeStruct((bucket.nnz,), f32),
        jax.ShapeDtypeStruct((bucket.rows, in_feat), f32),
        jax.ShapeDtypeStruct((in_feat, hidden), f32),
        jax.ShapeDtypeStruct((hidden, out_feat), f32),
    )


def spmm_nnz_example_args(bucket: CooBucket):
    i32, f32 = jnp.int32, jnp.float32
    return (
        jax.ShapeDtypeStruct((bucket.nnz,), i32),
        jax.ShapeDtypeStruct((bucket.nnz,), i32),
        jax.ShapeDtypeStruct((bucket.nnz,), f32),
        jax.ShapeDtypeStruct((bucket.cols, bucket.n), f32),
    )


def spmm_ell_example_args(bucket: EllBucket):
    i32, f32 = jnp.int32, jnp.float32
    return (
        jax.ShapeDtypeStruct((bucket.rows, bucket.slots), i32),
        jax.ShapeDtypeStruct((bucket.rows, bucket.slots), f32),
        jax.ShapeDtypeStruct((bucket.cols, bucket.n), f32),
    )
