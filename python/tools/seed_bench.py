#!/usr/bin/env python3
"""One-shot seeding tool for the committed BENCH_*.json trajectory files.

The authoritative generator is the Rust pipeline:

    cd rust && cargo run --release -- bench --quick --out ..
    # or: SGAP_BLESS=1 cargo test --test bench_json

This script transliterates the deterministic pieces of that pipeline —
SplitMix64, the dataset generators, MatrixStats/SegStats, the
`tuner::model::CostModel` pricing formulas, and the
`tuner::calibrate` coordinate-descent fitter (which seeds
CALIBRATION.json from the drift fixture `rust/tests/tuner_calibration.rs`
replays) — so the committed files can be seeded (schema-exact,
internally consistent, model-priced) in an environment without a Rust
toolchain. Because the seeded `est_time_us`
column is the *analytic model's* estimate rather than the simulator's,
`model_rank_agree` is trivially true in seeded files; the first blessed
run on a toolchain host replaces both (the schema validator and the
pruning-fidelity tests do not depend on the committed numbers).

Keep the formulas in sync with rust/src/tuner/model.rs when editing.
"""

import json
import math
import os
from collections import Counter

MASK = (1 << 64) - 1


class SplitMix64:
    """rust/src/sparse/rng.rs, bit-exact."""

    def __init__(self, seed):
        self.state = seed & MASK

    def next_u64(self):
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
        return (z ^ (z >> 31)) & MASK

    def below(self, bound):
        while True:
            x = self.next_u64()
            m = x * bound
            lo = m & MASK
            if lo >= bound or lo >= ((1 << 64) - bound) % bound:
                return m >> 64

    def uniform(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def value(self):
        return self.uniform() * 2.0 - 1.0

    def shuffle(self, xs):
        for i in range(len(xs) - 1, 0, -1):
            j = self.below(i + 1)
            xs[i], xs[j] = xs[j], xs[i]


# ---- generators (rust/src/sparse/gen.rs, degrees only) --------------------


def erdos_renyi_degrees(rows, cols, nnz, seed):
    rng = SplitMix64(seed)
    seen = set()
    deg = [0] * rows
    while len(seen) < nnz:
        r = rng.below(rows)
        c = rng.below(cols)
        if (r, c) not in seen:
            seen.add((r, c))
            deg[r] += 1
            rng.value()
    return deg


def power_law_degrees(rows, cols, nnz, alpha):
    """Exact-nnz Zipf degrees (gen.rs power_law). The generator's RNG only
    scatters which row gets which rank and which columns fill it; the
    degree *multiset* is the deterministic largest-remainder target, so no
    seed is needed for statistics."""
    nnz = min(nnz, rows * cols)
    weights = [float(k) ** -alpha for k in range(1, rows + 1)]
    total = sum(weights)
    exact = [w / total * nnz for w in weights]
    degrees = [min(int(math.floor(e)), cols) for e in exact]
    assigned = sum(degrees)
    # largest-remainder: descending fractional part, ties to the lower rank
    by_frac = sorted(range(rows), key=lambda i: (-(exact[i] - math.floor(exact[i])), i))
    k = 0
    while assigned < nnz:
        rank = by_frac[k % rows]
        if degrees[rank] < cols:
            degrees[rank] += 1
            assigned += 1
        k += 1
    return degrees


def block_community_degrees(n, blocks, intra_density, inter_nnz, seed):
    """gen.rs block_community, degree profile (RNG-faithful)."""
    rng = SplitMix64(seed)
    bs = n // blocks
    seen = set()
    deg = [0] * n
    for b in range(blocks):
        base = b * bs
        size = n - base if b == blocks - 1 else bs
        want = min(int((size * size) * intra_density), size * size)
        got = attempts = 0
        while got < want and attempts < want * 20 + 16:
            r = base + rng.below(size)
            c = base + rng.below(size)
            if (r, c) not in seen:
                seen.add((r, c))
                deg[r] += 1
                rng.value()
                got += 1
            attempts += 1
        if got < want:
            # near-dense block: fill the remainder from the shuffled free cells
            free = [
                (base + r, base + c)
                for r in range(size)
                for c in range(size)
                if (base + r, base + c) not in seen
            ]
            rng.shuffle(free)
            for r, c in free[: want - got]:
                seen.add((r, c))
                deg[r] += 1
                rng.value()
    inter = min(inter_nnz, n * n - len(seen))
    got = 0
    while got < inter:
        r = rng.below(n)
        c = rng.below(n)
        if (r, c) not in seen:
            seen.add((r, c))
            deg[r] += 1
            rng.value()
            got += 1
    return deg


def banded_degrees(n, band):
    half = band // 2
    return [min(i + half, n - 1) - max(i - half, 0) + 1 for i in range(n)]


def short_rows_degrees(n):
    return [2] * n


# ---- stats (rust/src/sparse/stats.rs) -------------------------------------


DEGREE_BUCKETS = 16


class MatrixStats:
    def __init__(self, rows, cols, degrees):
        self.rows = rows
        self.cols = cols
        self.nnz = sum(degrees)
        n = max(len(degrees), 1)
        self.row_degree_mean = self.nnz / n
        var = sum((d - self.row_degree_mean) ** 2 for d in degrees) / n
        self.row_degree_cv = math.sqrt(var) / self.row_degree_mean if self.row_degree_mean > 0 else 0.0
        self.row_degree_max = max(degrees) if degrees else 0
        self.empty_row_frac = sum(1 for d in degrees if d == 0) / n
        # log2 degree histogram (empty rows excluded) — the partitioner's input
        self.hist_rows = [0] * DEGREE_BUCKETS
        self.hist_nnz = [0] * DEGREE_BUCKETS
        for d in degrees:
            if d > 0:
                b = min(d.bit_length() - 1, DEGREE_BUCKETS - 1)
                self.hist_rows[b] += 1
                self.hist_nnz[b] += d


class SegStats:
    def __init__(self, segments, lengths):
        self.segments = segments
        self.nnz = sum(lengths)
        segs = max(segments, 1)
        self.mean_len = self.nnz / segs
        sumsq = sum(l * l for l in lengths)
        var = max(sumsq / segs - self.mean_len ** 2, 0.0)
        self.cv = math.sqrt(var) / self.mean_len if self.mean_len > 0 else 0.0
        self.max_len = max(lengths) if lengths else 0
        self.empty_frac = 1.0 - len(lengths) / segs


def coo3_random_segs(dims, nnz, seed):
    rng = SplitMix64(seed)
    d0, d1, d2 = dims
    seen = set()
    while len(seen) < min(nnz, d0 * d1 * d2):
        e = (rng.below(d0), rng.below(d1), rng.below(d2))
        if e not in seen:
            seen.add(e)
            rng.value()
    rows = Counter(a for a, _, _ in seen)
    fibers = Counter((a, b) for a, b, _ in seen)
    return (
        SegStats(d0, list(rows.values())),
        SegStats(d0 * d1, list(fibers.values())),
        len(seen),
    )


# ---- cost model (rust/src/tuner/model.rs, keep in sync) -------------------

ALU, LOAD, SHFL, SYNC, ATOMIC, BRANCH, BSEARCH = 1.0, 4.0, 2.0, 1.0, 4.0, 1.0, 6.0
LAUNCH = 2.0e-8  # HwProfile::rtx3090 launch_overhead_s
SM, CLOCK, BW, ISSUE = 68, 1.395e9, 936.0e9, 4.0  # RTX 3090
P, WARP = 256.0, 32.0

# θ = (7 CostParams in NAMES order, launch_overhead_s) — the vector
# tuner::calibrate::fit moves; set_theta mirrors calibrate::model_at
THETA_NAMES = ("alu", "load_issue", "shfl", "sync_per_lane", "atomic", "branch", "bsearch_step")
DEFAULT_THETA = (1.0, 4.0, 2.0, 1.0, 4.0, 1.0, 6.0, 2.0e-8)


def set_theta(theta):
    global ALU, LOAD, SHFL, SYNC, ATOMIC, BRANCH, BSEARCH, LAUNCH
    ALU, LOAD, SHFL, SYNC, ATOMIC, BRANCH, BSEARCH, LAUNCH = theta


def group_reduce(r, shfl_per_step):
    return math.log2(max(r, 1)) * (shfl_per_step * SHFL + SYNC * r)


def par_reduce(r):
    return group_reduce(r, 1.0)


def seg_scan(r):
    return group_reduce(r, 2.0)


def atomic_chain(m):
    return ATOMIC * max(m, 0.0)


def bsearch(window):
    steps = max(math.ceil(math.log2(max(window, 1.0))), 0.0)
    return BSEARCH * steps, steps


def dot_iter():
    return 2.0 * LOAD + 3.0 * ALU + BRANCH


def lockstep_degree(d_mean, cv, d_max):
    return min(max(d_mean * (1.0 + 2.0 * cv), d_mean), max(d_max, d_mean))


def boundary_prob(mean_len):
    return min(1.0 / max(mean_len, 1.0), 1.0)


def gather_sectors(entries, footprint_rows, width):
    return min(entries, max(footprint_rows * width / 8.0, 1.0))


def rollup(cycles, sectors, critical):
    t_compute = cycles / SM / ISSUE / CLOCK
    t_memory = sectors * 32.0 / BW
    t_latency = critical / CLOCK
    return max(t_compute, t_memory, t_latency) + LAUNCH


def est_nnz_group(s, n, c, r):
    z, d = s.nnz, s.row_degree_mean
    kch = max(n // c, 1)
    nnzb = P / kch
    blocks = max(math.ceil(z / nnzb), 1.0)
    warps = blocks * (P / WARP)
    pb = boundary_prob(d)
    bs_cy, bs_sec = bsearch(nnzb / max(d, 1.0) + 2.0)
    prologue = 4.0 * ALU + 2.0 * LOAD + bs_cy
    per_ki = (
        8.0 * ALU
        + 5.0 * LOAD
        + 2.0 * BRANCH
        + (1.0 + pb) * (ALU + LOAD)
        + seg_scan(r)
        + atomic_chain(min(max(d / r, 1.0), WARP / r))
    )
    per_warp = prologue + c * per_ki
    a_sectors = 8.0 + bs_sec + 2.0
    b_sectors = gather_sectors(WARP, s.cols, n)
    return rollup(warps * per_warp, warps * (a_sectors + b_sectors), per_warp)


def est_nnz_serial(s, n, g, c):
    z, d = s.nnz, s.row_degree_mean
    gf = float(g)
    kch = max(n // c, 1)
    nnzt = P / kch
    blocks = max(math.ceil(z / (gf * nnzt)), 1.0)
    warps = blocks * (P / WARP)
    pb = boundary_prob(d)
    flushes = gf * pb + 1.0
    bs_cy, bs_sec = bsearch(gf * nnzt / max(d, 1.0) + 2.0)
    prologue = 4.0 * ALU + 2.0 * LOAD + bs_cy
    per_ki = (
        gf * (3.0 * ALU + 2.0 * LOAD + BRANCH)
        + flushes * (2.0 * ALU + LOAD)
        + flushes * atomic_chain(min(max(d / gf, 1.0), WARP))
    )
    per_warp = prologue + c * per_ki
    a_sectors = 8.0 * gf + bs_sec + 2.0
    b_sectors = gather_sectors(WARP * gf, s.cols, n)
    return rollup(warps * per_warp, warps * (a_sectors + b_sectors), per_warp)


def est_row_serial(s, n, x, c):
    m, d = s.rows, s.row_degree_mean
    d_lock = lockstep_degree(d, s.row_degree_cv, s.row_degree_max)
    kch = max(n // c, 1)
    rowt = P / kch
    blocks = max(math.ceil(m / (x * rowt)), 1.0)
    warps = blocks * (P / WARP)
    row_cy = d_lock * dot_iter() + LOAD + 4.0 * ALU
    per_warp = 4.0 * ALU + (x * c) * row_cy
    critical = 4.0 * ALU + (x * c) * (s.row_degree_max * dot_iter())
    entries = WARP * x * d
    a_sectors = 2.0 * entries / 8.0 + 2.0
    b_sectors = gather_sectors(entries, s.cols, n)
    c_sectors = c * x * 4.0
    return rollup(
        warps * per_warp,
        warps * (a_sectors + b_sectors + c_sectors),
        max(critical, per_warp),
    )


def est_row_group(s, n, g, c, r):
    m, d = s.rows, s.row_degree_mean
    gf = float(g)
    kch = max(n // c, 1)
    rpb = max(P / (gf * kch), 1.0)
    blocks = max(math.ceil(m / rpb), 1.0)
    warps = blocks * (P / WARP)
    d_lock = lockstep_degree(d, s.row_degree_cv, s.row_degree_max)
    trips = math.ceil(d_lock / gf)
    wb_mult = max(gf / r, 1.0)
    per_ki = 4.0 * ALU + 2.0 * LOAD + trips * dot_iter() + par_reduce(r) + atomic_chain(wb_mult)
    per_warp = 6.0 * ALU + c * per_ki
    crit_trips = math.ceil(s.row_degree_max / gf)
    critical = 6.0 * ALU + c * (crit_trips * dot_iter() + par_reduce(r) + atomic_chain(wb_mult))
    rows_in_warp = max(WARP / (gf * kch), 1.0)
    entries = rows_in_warp * d
    a_sectors = 2.0 * entries / 8.0 + 2.0
    b_sectors = gather_sectors(entries, s.cols, n)
    return rollup(warps * per_warp, warps * (a_sectors + b_sectors), max(critical, per_warp))


def est_sddmm(s, j, g, r):
    """model.rs est_sddmm: `{<1/g nnz>, r}` grouped dense-j dot per nnz."""
    z = s.nnz
    jf, gf = float(j), float(g)
    npb = 256.0 / g  # SddmmConfig::npb, p = 256
    blocks = max(math.ceil(z / npb), 1.0)
    warps = blocks * (P / WARP)
    iters = max(math.ceil(jf / gf), 1.0)
    per_warp = (
        6.0 * ALU
        + 3.0 * LOAD
        + iters * (2.0 * LOAD + 3.0 * ALU + BRANCH)
        + ALU
        + par_reduce(r)
        + atomic_chain(max(gf / r, 1.0))
    )
    groups = WARP / gf
    meta_sectors = 3.0 * max(groups / 8.0, 1.0)
    x1_sectors = groups * max(jf / 8.0, 1.0)
    x2_sectors = gather_sectors(groups * jf, jf, s.cols)
    return rollup(warps * per_warp, warps * (meta_sectors + x1_sectors + x2_sectors), per_warp)


def est_fused(s, j, n, c, r):
    """model.rs est_fused: the one-kernel SDDMM→SpMM chain — the
    nnz-group skeleton with the producer's dot hoisted per nnz and no
    intermediate write/re-read."""
    z, d = s.nnz, s.row_degree_mean
    jf = float(j)
    kch = max(n // c, 1)
    nnzb = P / kch
    blocks = max(math.ceil(z / nnzb), 1.0)
    warps = blocks * (P / WARP)
    pb = boundary_prob(d)
    bs_cy, bs_sec = bsearch(nnzb / max(d, 1.0) + 2.0)
    prologue = (
        4.0 * ALU
        + 2.0 * LOAD
        + bs_cy
        + (1.0 + pb) * (ALU + LOAD)
        + jf * dot_iter()
        + ALU
    )
    per_ki = (
        8.0 * ALU
        + 4.0 * LOAD
        + 2.0 * BRANCH
        + seg_scan(r)
        + atomic_chain(min(max(d / r, 1.0), WARP / r))
    )
    per_warp = prologue + c * per_ki
    a_sectors = 8.0 + bs_sec + 2.0
    b_sectors = gather_sectors(WARP, s.cols, n)
    x1_sectors = gather_sectors(WARP * max(jf / 8.0, 1.0), s.rows, jf)
    x2_sectors = gather_sectors(WARP * jf, jf, s.cols)
    return rollup(
        warps * per_warp,
        warps * (a_sectors + b_sectors + x1_sectors + x2_sectors),
        per_warp,
    )


class DgConfig:
    """rust/src/compiler/schedule.rs DgConfig, the derived shapes only."""

    def __init__(self, n, group_sz, block_sz, tile_sz, frac, worker_sz, coarsen_sz):
        self.n, self.group_sz, self.block_sz = n, group_sz, block_sz
        self.tile_sz, self.frac, self.worker_sz, self.coarsen_sz = tile_sz, frac, worker_sz, coarsen_sz

    @staticmethod
    def stock(n):
        coarsen = 4 if n % 4 == 0 else (2 if n % 2 == 0 else 1)
        return DgConfig(n, 32, 256, 32, 1.0, 32, coarsen)

    def vcols(self):
        return min(self.n, self.tile_sz) // max(self.coarsen_sz, 1)

    def block_dim_x(self):
        return self.vcols() * self.worker_sz

    def rows_per_block(self):
        return max(self.block_sz // max(self.block_dim_x(), 1), 1)

    def col_tiles(self):
        return -(-self.n // self.tile_sz)

    def validate(self):
        g = self.group_sz
        if g & (g - 1) or g > 32 or g > self.worker_sz:
            return False
        t = self.tile_sz
        if t & (t - 1) or t < g:
            return False
        if self.coarsen_sz == 0 or min(self.n, t) % self.coarsen_sz != 0:
            return False
        if self.block_dim_x() > self.block_sz or self.block_sz > 1024:
            return False
        if self.block_sz % max(self.block_dim_x(), 1) != 0:
            return False
        return self.frac > 0.0

    def worker_dim_r(self, rows):
        rpb = self.rows_per_block()
        want = max(int(round_half_away(rows * self.frac)), rpb)
        return -(-want // rpb) * rpb

    def name(self):
        frac = int(self.frac) if self.frac == int(self.frac) else self.frac
        return f"dg<{self.group_sz},{self.block_sz},{self.tile_sz},{frac}>"


def round_half_away(x):
    # Rust f64::round() rounds half away from zero
    return math.floor(x + 0.5) if x >= 0 else math.ceil(x - 0.5)


def est_dg(s, cfg):
    m, d = s.rows, s.row_degree_mean
    ws = float(cfg.worker_sz)
    coarsen = float(cfg.coarsen_sz)
    vcols = float(max(cfg.vcols(), 1))
    col_tiles = float(max(cfg.col_tiles(), 1))
    d_lock = lockstep_degree(d, s.row_degree_cv, s.row_degree_max)
    unit_cy = coarsen * (
        2.0 * ALU
        + math.ceil(d_lock / ws) * dot_iter()
        + par_reduce(cfg.group_sz)
        + atomic_chain(max(ws / cfg.group_sz, 1.0))
    )
    units = m * vcols * col_tiles
    cycles = units * unit_cy * (ws / WARP)
    visits = max(math.ceil(m / max(cfg.worker_dim_r(m), 1)), 1.0)
    critical = visits * coarsen * (
        math.ceil(s.row_degree_max / ws) * dot_iter() + par_reduce(cfg.group_sz)
    )
    a_sectors = units * (2.0 * d / 8.0 + 2.0)
    b_sectors = max(gather_sectors(units * d, s.cols, cfg.n), units * d / 8.0)
    return rollup(cycles, a_sectors + b_sectors, critical)


def est_coo3(seg, width, c, r, with_x2):
    z = seg.nnz
    used = max(seg.segments * (1.0 - seg.empty_frac), 1.0)
    d_used = z / used
    kch = max(width // c, 1)
    npb = P / kch
    blocks = max(math.ceil(z / npb), 1.0)
    warps = blocks * (P / WARP)
    factors = 2.0 if with_x2 else 1.0
    loads = 2.0 + 2.0 * factors
    per_ki = (
        8.0 * ALU
        + loads * LOAD
        + 2.0 * BRANCH
        + seg_scan(r)
        + atomic_chain(min(max(d_used / r, 1.0), WARP / r))
    )
    per_warp = 6.0 * ALU + LOAD + c * per_ki
    meta_sectors = 8.0 + 4.0 * factors
    x_sectors = factors * WARP
    return rollup(warps * per_warp, warps * (meta_sectors + x_sectors), per_warp)


# ---- candidate grids (rust/src/tuner/space.rs) ----------------------------


def c_values(n):
    return [c for c in (1, 2, 4) if n % c == 0 and 256 % (n // c) == 0]


def families_grid(n):
    out = []
    for c in c_values(n):
        kch = n // c
        for g in (4, 8, 16, 32):
            out.append(("taco-nnz", g, c, None, f"taco{{<{g} nnz,{c} col>,1}}"))
        for x in (1, 2, 4):
            out.append(("taco-row", x, c, None, f"taco{{<{x} row,{c} col>,1}}"))
        for r in (2, 4, 8, 16, 32):
            out.append(("sgap-nnz", None, c, r, f"sgap{{<1 nnz,{c} col>,{r}}}"))
            for g in (2, 4, 8, 16, 32):
                if r <= g and 256 % (g * kch) == 0 and 256 // (g * kch) >= 1:
                    out.append(("sgap-row", g, c, r, f"sgap{{<1/{g} row,{c} col>,{r}}}"))
    return out


def price_family(kind, g, c, r, s, n):
    if kind == "taco-nnz":
        return est_nnz_serial(s, n, g, c)
    if kind == "taco-row":
        return est_row_serial(s, n, g, c)
    if kind == "sgap-nnz":
        return est_nnz_group(s, n, c, r)
    return est_row_group(s, n, g, c, r)


def dg_grid_small(n):
    stock = DgConfig.stock(n)
    out = []
    for group_sz in (2, 4, 8, 16, 32):
        for tile_sz in (group_sz, 8, 32):
            if tile_sz < group_sz or tile_sz & (tile_sz - 1):
                continue
            for frac in (0.5, 1.0):
                cfg = DgConfig(
                    n, group_sz, 256, tile_sz, frac, stock.worker_sz,
                    min(stock.coarsen_sz, min(n, tile_sz)),
                )
                if cfg.validate() and all(c.name() != cfg.name() for c in out):
                    out.append(cfg)
    return out


def coo3_grid(width):
    out = []
    for c in c_values(width):
        kch = width // c
        npb = 256 // kch
        for r in (2, 4, 8, 16, 32):
            if r <= min(npb, 32):
                out.append((c, r))
    return out


def sddmm_grid(j):
    """tuner::space::sddmm_candidates order: g outer, r inner, r <= g."""
    return [(g, r) for g in (2, 4, 8, 16, 32) for r in (2, 4, 8, 16, 32) if r <= g]


def fused_grid(j, n):
    """tuner::space::fused_candidates order: c (from c_values) outer, r
    inner, FusedConfig::validate's `r <= npb` rule."""
    out = []
    for c in c_values(n):
        npb = 256 // max(n // c, 1)
        for r in (2, 4, 8, 16, 32):
            if r <= min(npb, 32):
                out.append((c, r))
    return out


def band_grid(n):
    """tuner::space::band_candidates, in its exact order (taco block then
    sgap block) — shortlist ties break by grid index, so the order is part
    of the contract."""
    out = []
    for c in c_values(n):
        for g in (4, 8, 16, 32):
            out.append(("taco-nnz", g, c, None, f"taco{{<{g} nnz,{c} col>,1}}"))
        for x in (1, 2, 4):
            out.append(("taco-row", x, c, None, f"taco{{<{x} row,{c} col>,1}}"))
    for c in c_values(n):
        kch = n // c
        for r in (2, 4, 8, 16, 32):
            out.append(("sgap-nnz", None, c, r, f"sgap{{<1 nnz,{c} col>,{r}}}"))
            for g in (2, 4, 8, 16, 32):
                if r <= g and 256 % (g * kch) == 0 and 256 // (g * kch) >= 1:
                    out.append(("sgap-row", g, c, r, f"sgap{{<1/{g} row,{c} col>,{r}}}"))
    return out


# ---- band partitioner (rust/src/sparse/partition.rs) -----------------------

CUT_SENTINEL = DEGREE_BUCKETS


def choose_cuts(s):
    total = sum(s.hist_nnz)
    if total == 0:
        return None
    occupied = [b for b in range(DEGREE_BUCKETS) if s.hist_rows[b] > 0]
    if len(occupied) < 2:
        return None
    lowest, top = occupied[0], occupied[-1]
    max_bucket = max(s.hist_nnz)
    prefix = [0] * (DEGREE_BUCKETS + 1)
    for b in range(DEGREE_BUCKETS):
        prefix[b + 1] = prefix[b] + s.hist_nnz[b]

    def cut_at(k, bands):
        c = next(
            (c for c in range(1, DEGREE_BUCKETS + 1) if prefix[c] * bands >= k * total),
            DEGREE_BUCKETS,
        )
        return min(max(c, lowest + 1), top)

    if len(occupied) >= 3:
        c1, c2 = cut_at(1, 3), cut_at(2, 3)
        if c1 < c2:
            widths = [(0, c1), (c1, c2), (c2, DEGREE_BUCKETS)]
            bound = total // 3 + max_bucket
            balanced = all(prefix[hi] - prefix[lo] <= bound for lo, hi in widths)
            populated = all(
                any(s.hist_rows[b] > 0 for b in range(lo, hi)) for lo, hi in widths
            )
            if balanced and populated:
                return 3, (c1, c2)
    return 2, (cut_at(1, 2), CUT_SENTINEL)


class _BandStats:
    """Synthetic per-band stats (partition.rs band_stats) — the fields the
    pricing formulas read."""

    def __init__(self, rows, cols, nnz, mean, cv, max_deg):
        self.rows, self.cols, self.nnz = rows, cols, nnz
        self.row_degree_mean, self.row_degree_cv = mean, cv
        self.row_degree_max = max_deg


def band_stats(s, bands, cuts):
    empty_rows = int(round_half_away(s.empty_row_frac * s.rows))
    out = []
    for band in range(bands):
        lo = 0 if band == 0 else cuts[band - 1]
        hi = cuts[band] if band + 1 < bands else DEGREE_BUCKETS
        rows_b = sum(s.hist_rows[b] for b in range(lo, hi))
        nnz_b = sum(s.hist_nnz[b] for b in range(lo, hi))
        occ = [b for b in range(lo, hi) if s.hist_rows[b] > 0]
        empties = empty_rows if band == 0 else 0
        rows_total = max(rows_b + empties, 1)
        mean = nnz_b / rows_total
        var = empties * mean * mean
        for b in range(lo, hi):
            rep = 1.5 * (1 << b)
            var += s.hist_rows[b] * (rep - mean) * (rep - mean)
        var /= rows_total
        cv = math.sqrt(var) / mean if mean > 0.0 else 0.0
        max_deg = min((1 << (occ[-1] + 1)) - 1, s.row_degree_max) if occ else 0
        out.append(_BandStats(rows_total, s.cols, nnz_b, mean, cv, max_deg))
    return out


def banded_report(s, n):
    """tuner::selector::Selector::banded_report: the composite candidate
    (best plan per band, priced on synthetic band stats; composite price =
    slowest band plus one extra launch overhead per additional band) vs
    the best single plan on the same band grid. Returns
    (hybrid_name, t_composite, single_name, t_single, bands, grid_len)."""
    cut = choose_cuts(s)
    if cut is None:
        return None
    bands, cuts = cut
    grid = band_grid(n)
    if not grid:
        return None
    per = band_stats(s, bands, cuts)
    names = []
    t_comp = 0.0
    for bs in per:
        price, idx = min(
            (price_family(k, g, c, r, bs, n), i)
            for i, (k, g, c, r, _) in enumerate(grid)
        )
        names.append(grid[idx][4])
        t_comp = max(t_comp, price)
    t_comp += (bands - 1.0) * LAUNCH
    hybrid = "hybrid{" + " | ".join(names) + f" @cuts[{cuts[0]},{cuts[1]}]" + "}"
    t_single, best_idx = min(
        (price_family(k, g, c, r, s, n), i) for i, (k, g, c, r, _) in enumerate(grid)
    )
    return hybrid, t_comp, grid[best_idx][4], t_single, bands, len(grid)


# ---- calibration fitter (rust/src/tuner/calibrate.rs, keep in sync) --------

MIN_PARAM = 1e-6
FACTORS = (2.0, 1.5, 1.25, 1.1, 1.05, 1.02, 1.01)
PASSES_PER_FACTOR = 2
THETA_N = 8


def fit_loss(theta, samples):
    """calibrate::fit_loss: mean squared log-ratio at theta. `samples` is
    a list of (price_fn, measured_s); price_fn reads the globals."""
    saved = (ALU, LOAD, SHFL, SYNC, ATOMIC, BRANCH, BSEARCH, LAUNCH)
    set_theta(theta)
    acc = 0.0
    used = 0
    try:
        for price_fn, measured in samples:
            if not (math.isfinite(measured) and measured > 0.0):
                continue
            t = price_fn()
            if t is None or not (math.isfinite(t) and t > 0.0):
                continue
            r = math.log(t) - math.log(measured)
            acc += r * r
            used += 1
    finally:
        set_theta(saved)
    return (math.inf, 0) if used == 0 else (acc / used, used)


def fit(samples, start=DEFAULT_THETA):
    """calibrate::fit: deterministic cyclic coordinate descent — for each
    factor (coarse → fine), two passes over the coordinates in order,
    trying θi·f and θi/f, accepting only strict improvements. Returns
    (theta, loss_before, loss_after, used)."""
    theta = list(start)
    before, used = fit_loss(theta, samples)
    assert used > 0, "fit needs at least one usable sample"
    best = before
    for f in FACTORS:
        for _ in range(PASSES_PER_FACTOR):
            for i in range(THETA_N):
                for cand in (theta[i] * f, theta[i] / f):
                    cand = max(cand, MIN_PARAM) if i < THETA_N - 1 else max(cand, 0.0)
                    trial = list(theta)
                    trial[i] = cand
                    loss, _ = fit_loss(trial, samples)
                    if loss < best:
                        best = loss
                        theta = trial
    return theta, before, best, used


def spearman(xs, ys):
    """calibrate::spearman (rank correlation, no tie correction)."""

    def ranks(v):
        idx = sorted(range(len(v)), key=lambda i: v[i])
        r = [0.0] * len(v)
        for rank, i in enumerate(idx):
            r[i] = float(rank)
        return r

    rx, ry = ranks(xs), ranks(ys)
    n = float(len(xs))
    mean = (n - 1.0) / 2.0
    cov = vx = vy = 0.0
    for i in range(len(xs)):
        cov += (rx[i] - mean) * (ry[i] - mean)
        vx += (rx[i] - mean) ** 2
        vy += (ry[i] - mean) ** 2
    return cov / max(math.sqrt(vx) * math.sqrt(vy), 1e-12)


def fmt_calib(x):
    """Rust `{:.17e}`: 18 significant digits, exponent with no '+' and no
    leading zeros (`2.00000000000000000e-8`, `1.00000000000000000e0`)."""
    mant, _, exp = f"{x:.17e}".partition("e")
    sign = "-" if exp.startswith("-") else ""
    digits = exp.lstrip("+-").lstrip("0") or "0"
    return f"{mant}e{sign}{digits}"


def emit_calibration(path, samples, loss_before, loss_after, theta):
    """Byte-layout mirror of tuner::calibrate::Calibration::to_json."""
    out = []
    out.append("{")
    out.append('  "schema_version": 1,')
    out.append('  "hw": "RTX 3090",')
    out.append(f'  "samples": {samples},')
    out.append(f'  "loss_before": {fmt_calib(loss_before)},')
    out.append(f'  "loss_after": {fmt_calib(loss_after)},')
    out.append(f'  "launch_overhead_s": {fmt_calib(theta[7])},')
    out.append('  "params": {')
    for i, name in enumerate(THETA_NAMES):
        comma = "," if i + 1 < len(THETA_NAMES) else ""
        out.append(f'    "{name}": {fmt_calib(theta[i])}{comma}')
    out.append("  }")
    out.append("}")
    text = "\n".join(out) + "\n"
    json.loads(text)  # sanity: well-formed
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {path}: {samples} samples, loss {loss_before:.4f} -> {loss_after:.4f}")


# ---- the report ------------------------------------------------------------

GEN_NOTE = (
    "; numbers seeded from the analytic model (python/tools/seed_bench.py) "
    "pending a toolchain run - regenerate with `SGAP_BLESS=1 cargo test --test bench_json`"
)
TOP_K = 8


def geomean(xs):
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def fmt(x):
    return f"{x:.4f}"


def emit(path, suite, generator, rows):
    speedups = [r["speedup_vs_baseline"] for r in rows]
    agree = sum(1 for r in rows if r["model_rank_agree"]) / len(rows)
    out = []
    out.append("{")
    out.append('  "schema_version": 1,')
    out.append(f'  "suite": "{suite}",')
    out.append(f'  "generator": "{generator}",')
    out.append('  "hw": "RTX 3090",')
    out.append('  "quick": true,')
    out.append(f'  "top_k": {TOP_K},')
    out.append(f'  "geomean_speedup": {fmt(geomean(speedups))},')
    out.append(f'  "rank_agreement": {fmt(agree)},')
    out.append('  "rows": [')
    for i, r in enumerate(rows):
        out.append("    {")
        out.append(f'      "bench": "{r["bench"]}",')
        out.append(f'      "matrix": "{r["matrix"]}",')
        out.append(f'      "family": "{r["family"]}",')
        out.append(f'      "width": {r["width"]},')
        out.append(f'      "algo": "{r["algo"]}",')
        out.append(f'      "baseline": "{r["baseline"]}",')
        out.append(f'      "est_time_us": {fmt(r["est_time_us"])},')
        out.append(f'      "baseline_time_us": {fmt(r["baseline_time_us"])},')
        out.append(f'      "gflops": {fmt(r["gflops"])},')
        out.append(f'      "speedup_vs_baseline": {fmt(r["speedup_vs_baseline"])},')
        out.append('      "model_rank_agree": true,')
        out.append(f'      "grid": {r["grid"]},')
        out.append(f'      "survivors": {r["survivors"]}')
        out.append("    }" + ("," if i + 1 < len(rows) else ""))
    out.append("  ]")
    out.append("}")
    text = "\n".join(out) + "\n"
    json.loads(text)  # sanity: well-formed
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {path}: {len(rows)} rows, geomean {geomean(speedups):.3f}")


def row(bench, matrix, family, width, algo, baseline, est_s, base_s, flops, grid, survivors):
    return {
        "bench": bench,
        "matrix": matrix,
        "family": family,
        "width": width,
        "algo": algo,
        "baseline": baseline,
        "est_time_us": est_s * 1e6,
        "baseline_time_us": base_s * 1e6,
        "gflops": flops / est_s / 1e9,
        "speedup_vs_baseline": base_s / est_s,
        "model_rank_agree": True,
        "grid": grid,
        "survivors": survivors,
    }


def main():
    root = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "..")
    n = 4
    # the quick (mini) suite, with dataset::suite()'s sequential seeds
    mini = [
        ("er_1024_d5e-3", "erdos_renyi",
         MatrixStats(1024, 1024, erdos_renyi_degrees(1024, 1024, 5242, 1002))),
        ("pl_1024_a1.8", "power_law",
         MatrixStats(1024, 1024, power_law_degrees(1024, 1024, 8192, 1.8))),
        ("band_1024_w5", "banded", MatrixStats(1024, 1024, banded_degrees(1024, 5))),
        ("corner_short_rows_2048", "corner",
         MatrixStats(2048, 2048, short_rows_degrees(2048))),
    ]

    spmm_rows = []
    for name, family, s in mini:
        grid = families_grid(n)
        priced = sorted(
            (price_family(k, g, c, r, s, n), algo) for (k, g, c, r, algo) in grid
        )
        best_t, best_algo = priced[0]
        base_t = est_row_group(s, n, 32, 4, 32)
        spmm_rows.append(row(
            "families", name, family, n, best_algo, "sgap{<1/32 row,4 col>,32}",
            best_t, base_t, 2 * s.nnz * n, len(grid), TOP_K,
        ))
        dg = dg_grid_small(n)
        priced = sorted((est_dg(s, cfg), cfg.name()) for cfg in dg)
        best_t, best_algo = priced[0]
        stock = DgConfig.stock(n)
        spmm_rows.append(row(
            "dgsparse", name, family, n, best_algo, stock.name(),
            best_t, est_dg(s, stock), 2 * s.nnz * n, len(dg), min(TOP_K, len(dg)),
        ))

    # the skew table (bench_util.rs run_spmm_bench): per-band hybrid vs the
    # best single band-grid plan, both analytic prices — dataset::suite()
    # seeds 1013 / 1016 / 1021 (the power-law degrees are seed-free)
    skew = [
        ("pl_2048_a1.6", "power_law",
         MatrixStats(2048, 2048, power_law_degrees(2048, 2048, 16384, 1.6))),
        ("pl_4096_a2", "power_law",
         MatrixStats(4096, 4096, power_law_degrees(4096, 4096, 32768, 2.0))),
        ("block_2048_b16", "block_community",
         MatrixStats(2048, 2048, block_community_degrees(2048, 16, 0.02, 4000, 1021))),
    ]
    beat = False
    for name, family, s in skew:
        rep = banded_report(s, n)
        assert rep is not None, f"{name}: skew matrix declined banding"
        hybrid, t_comp, single, t_single, bands, grid_len = rep
        assert t_comp <= t_single, (
            f"{name}: hybrid priced above best single plan ({t_comp:.3e} > {t_single:.3e})"
        )
        beat = beat or t_comp < t_single
        spmm_rows.append(row(
            "skew", name, family, n, hybrid, single, t_comp, t_single, 0, grid_len, bands,
        ))
    assert beat, "no skew row where the hybrid strictly beats the best single plan"

    # the fused table (bench_util.rs run_spmm_bench): the one-kernel
    # SDDMM→SpMM chain vs the best two-stage pipeline, analytic prices at
    # J=32, N=4 — er_2048_d2e-3 is dataset::suite() seed 1005; the banded
    # degrees are seed-free; er_128_d2e-1 is fused_suite()'s own spec
    def cheapest(priced):
        """bench_util.rs cheapest: strictly-less scan in grid order."""
        best_t, best_name = priced[0]
        for t, name in priced[1:]:
            if t < best_t:
                best_t, best_name = t, name
        return best_t, best_name

    j_fused = 32
    fused = [
        ("er_2048_d2e-3", "erdos_renyi",
         MatrixStats(2048, 2048, erdos_renyi_degrees(2048, 2048, 8388, 1005))),
        ("band_2048_w9", "banded", MatrixStats(2048, 2048, banded_degrees(2048, 9))),
        ("er_128_d2e-1", "erdos_renyi",
         MatrixStats(128, 128, erdos_renyi_degrees(128, 128, 3276, 77))),
    ]
    fgrid = fused_grid(j_fused, n)
    headline = False
    for name, family, s in fused:
        t_fused, fused_name = cheapest([
            (est_fused(s, j_fused, n, c, r), f"fused{{<1 nnz,{c} col>,{r}}}")
            for (c, r) in fgrid
        ])
        t_sddmm, sddmm_name = cheapest([
            (est_sddmm(s, j_fused, g, r), f"sddmm{{<1/{g} nnz>,{r}}}")
            for (g, r) in sddmm_grid(j_fused)
        ])
        t_spmm, spmm_name = cheapest([
            (price_family(k, g, c, r, s, n), algo)
            for (k, g, c, r, algo) in band_grid(n)
        ])
        t_two = t_sddmm + t_spmm
        assert t_fused <= t_two, (
            f"{name}: fused kernel priced above the two-stage pipeline it replaces "
            f"({t_fused:.3e} > {t_two:.3e})"
        )
        headline = headline or t_two / t_fused >= 1.5
        spmm_rows.append(row(
            "fused", name, family, n, fused_name, f"{sddmm_name} + {spmm_name}",
            t_fused, t_two, 0, len(fgrid), 1,
        ))
    assert headline, "no fused row at >= 1.5x over the two-stage pipeline"

    emit(
        os.path.join(root, "BENCH_spmm.json"), "spmm",
        f"sgap bench --quick (spmm, N={n})" + GEN_NOTE, spmm_rows,
    )

    width = 16
    tensors = [
        ("coo3_uniform_128x96x64", "uniform", (128, 96, 64), 4000, 7),
        ("coo3_dense_rows_64", "dense-rows", (64, 48, 32), 6000, 9),
        ("coo3_sparse_rows_512", "sparse-rows", (512, 64, 32), 2000, 11),
    ]
    tensor_rows = []
    for name, family, dims, nnz, seed in tensors:
        rows_seg, fiber_seg, z = coo3_random_segs(dims, nnz, seed)
        grid = coo3_grid(width)
        priced = sorted(
            (est_coo3(rows_seg, width, c, r, True),
             f"mttkrp{{<1 nnz,{c} col>,{r}}}") for (c, r) in grid
        )
        best_t, best_algo = priced[0]
        base_t = est_coo3(rows_seg, width, 4, 32, True)
        tensor_rows.append(row(
            "mttkrp", name, family, width, best_algo, "mttkrp{<1 nnz,4 col>,32}",
            best_t, base_t, 3 * z * width, len(grid), min(TOP_K, len(grid)),
        ))
        priced = sorted(
            (est_coo3(fiber_seg, width, c, r, False),
             f"ttm{{<1 nnz,{c} col>,{r}}}") for (c, r) in grid
        )
        best_t, best_algo = priced[0]
        base_t = est_coo3(fiber_seg, width, 4, 32, False)
        tensor_rows.append(row(
            "ttm", name, family, width, best_algo, "ttm{<1 nnz,4 col>,32}",
            best_t, base_t, 2 * z * width, len(grid), min(TOP_K, len(grid)),
        ))
    emit(
        os.path.join(root, "BENCH_tensor.json"), "tensor",
        f"sgap bench --quick (tensor, J=L={width})" + GEN_NOTE, tensor_rows,
    )

    # ---- CALIBRATION.json (rust/tests/tuner_calibration.rs drift fixture) --
    # Ground truth = the analytic model with drifted constants θ*; the
    # "measurements" are mini-suite × families-grid prices under θ*.
    # Fitting from the defaults must cut the loss AND strictly improve the
    # mean Spearman rank fidelity — the invariants the Rust test asserts,
    # verified numerically here before the artifact is committed.
    DRIFT = (1.8, 0.55, 1.6, 2.4, 0.45, 1.5, 2.0)
    truth = tuple(DEFAULT_THETA[i] * DRIFT[i] for i in range(7)) + (DEFAULT_THETA[7] * 4.0,)
    grid = families_grid(n)

    def pricer(k, g, c, r, s):
        return lambda: price_family(k, g, c, r, s, n)

    set_theta(truth)
    per_matrix = []  # (name, stats, measured prices in grid order)
    samples = []
    for name, family, s in mini:
        measured = [price_family(k, g, c, r, s, n) for (k, g, c, r, _) in grid]
        per_matrix.append((name, s, measured))
        for (k, g, c, r, _), t in zip(grid, measured):
            samples.append((pricer(k, g, c, r, s), t))
    set_theta(DEFAULT_THETA)

    theta_fit, loss_before, loss_after, used = fit(samples)
    assert loss_after < loss_before * 0.9, (
        f"fit must cut the drift loss by >= 10% ({loss_before:.4f} -> {loss_after:.4f})"
    )

    def mean_spearman(theta):
        set_theta(theta)
        vals = []
        for _, s, measured in per_matrix:
            preds = [price_family(k, g, c, r, s, n) for (k, g, c, r, _) in grid]
            vals.append(spearman(preds, measured))
        set_theta(DEFAULT_THETA)
        return sum(vals) / len(vals)

    sp_before = mean_spearman(DEFAULT_THETA)
    sp_after = mean_spearman(tuple(theta_fit))
    assert sp_after > sp_before, (
        f"fit must strictly improve mean rank fidelity ({sp_before:.4f} -> {sp_after:.4f})"
    )
    print(f"drift fixture: spearman {sp_before:.4f} -> {sp_after:.4f}")
    emit_calibration(
        os.path.join(root, "CALIBRATION.json"), used, loss_before, loss_after, theta_fit
    )


if __name__ == "__main__":
    main()
